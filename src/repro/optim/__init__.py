from repro.optim.optimizers import Optimizer, make  # noqa: F401
