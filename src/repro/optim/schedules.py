"""Learning-rate schedules (pure functions step -> scale factor)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.asarray(1.0)


def linear_warmup(warmup_steps: int):
    def fn(step):
        return jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
    return fn


def cosine_decay(total_steps: int, warmup_steps: int = 0,
                 final_scale: float = 0.1):
    """Linear warmup then cosine decay to final_scale."""
    def fn(step):
        warm = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        t = jnp.clip((step - warmup_steps) /
                     max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_scale + (1 - final_scale) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return warm * cos
    return fn


def make(name: str, total_steps: int, warmup_steps: int = 0):
    if name == "constant":
        return constant()
    if name == "warmup":
        return linear_warmup(warmup_steps)
    if name == "cosine":
        return cosine_decay(total_steps, warmup_steps)
    raise KeyError(f"unknown schedule {name!r}")


def scale_updates(updates, scale):
    import jax
    return jax.tree.map(lambda u: u * scale.astype(u.dtype)
                        if hasattr(u, "dtype") else u, updates)
