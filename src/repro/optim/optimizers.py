"""First-order optimizers (optax-style, self-contained).

Includes the paper's §4.2 comparison methods — GD, Adam, Adagrad, Adadelta —
plus momentum/AdamW used by the transformer substrate. ``update`` returns the
*delta* to add to params (optax convention).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _tree_zeros(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return _tree_zeros(params)

    def update(grads, vel, params=None):
        vel = jax.tree.map(lambda v, g: beta * v + g, vel, grads)
        return jax.tree.map(lambda v: -lr * v, vel), vel

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Adam with f32 moments (params may be bf16 — deltas cast back)."""

    def init(params):
        def f32(p):
            return jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                         g.astype(jnp.float32) * g.astype(jnp.float32),
                         state["v"], grads)
        mh_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
        vh_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))

        def delta(m, v, p):
            d = -lr * (m * mh_scale) / (jnp.sqrt(v * vh_scale) + eps)
            if weight_decay and p is not None:
                d = d - lr * weight_decay * p.astype(jnp.float32)
            return d.astype(p.dtype) if p is not None else d

        if params is None:
            deltas = jax.tree.map(lambda m, v: delta(m, v, None), m, v)
        else:
            deltas = jax.tree.map(delta, m, v, params)
        return deltas, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adagrad(lr: float, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return _tree_zeros(params)

    def update(grads, acc, params=None):
        acc = jax.tree.map(lambda a, g: a + g * g, acc, grads)
        deltas = jax.tree.map(lambda g, a: -lr * g / (jnp.sqrt(a) + eps),
                              grads, acc)
        return deltas, acc

    return Optimizer(init, update)


def adadelta(lr: float = 1.0, rho: float = 0.95,
             eps: float = 1e-6) -> Optimizer:
    def init(params):
        return {"acc_g": _tree_zeros(params), "acc_d": _tree_zeros(params)}

    def update(grads, state, params=None):
        acc_g = jax.tree.map(lambda a, g: rho * a + (1 - rho) * g * g,
                             state["acc_g"], grads)
        deltas = jax.tree.map(
            lambda g, ag, ad: -lr * g * jnp.sqrt(ad + eps) / jnp.sqrt(ag + eps),
            grads, acc_g, state["acc_d"])
        acc_d = jax.tree.map(lambda a, d: rho * a + (1 - rho) * d * d,
                             state["acc_d"], deltas)
        return deltas, {"acc_g": acc_g, "acc_d": acc_d}

    return Optimizer(init, update)


_REGISTRY: dict[str, Callable[..., Optimizer]] = {
    "gd": sgd, "sgd": sgd, "momentum": momentum, "adam": adam,
    "adamw": lambda lr, **kw: adam(lr, weight_decay=kw.pop("weight_decay", 0.1), **kw),
    "adagrad": adagrad, "adadelta": adadelta,
}


def make(name: str, lr: float, **kwargs) -> Optimizer:
    if name not in _REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; have {list(_REGISTRY)}")
    return _REGISTRY[name](lr, **kwargs)
