"""Multilevel coarsen→partition→uncoarsen graph partitioner (METIS scheme).

Pure-numpy implementation of the three-phase multilevel scheme that METIS
(Karypis & Kumar, 1998) made standard, and that Cluster-GCN relies on for
community-batched GCN training.  Same contract as
``repro.core.graph.partition_graph`` — ``(N,) int32`` community ids, every
node assigned exactly once, part sizes under the hard cap ``ceil(N / M)`` —
so it drops into ``build_community_layout``, the trainers, benchmarks and
examples unchanged (exposed as ``partition_graph(method="multilevel")``).

Phase map (METIS name → function here):

  1. **Coarsening** (``_heavy_edge_matching`` + ``_contract``): repeated
     heavy-edge matching — visit vertices in random order, match each with
     its unmatched neighbour of maximum edge weight — then contract matched
     pairs into coarse vertices, summing node weights and accumulating
     parallel edge weights.  Dense regions (heavy accumulated edges)
     collapse first, so community structure survives coarsening.  Stops at
     ``coarsen_to`` vertices or when matching stalls (< 5% shrink).
  2. **Initial partitioning** (``_initial_partition``): on the coarsest
     graph, weight-aware BFS-grown seeds under a slackened weight cap
     (the greedy part of METIS' GGGP), followed by weighted
     Kernighan–Lin boundary refinement.
  3. **Uncoarsening** (``_refine`` at every level): project the partition
     through the matching maps and re-run boundary KL refinement at each
     finer level — moves are taken in descending-gain order (integer
     edge-weight gains, i.e. an array-sorted stand-in for the classic
     gain-bucket queue) under the level's weight cap.  At the finest level
     node weights are all one, so ``_enforce_cap`` can restore the strict
     ``ceil(N / M)`` balance cap exactly, moving minimum-cut-loss boundary
     nodes out of overfull parts.

Determinism: all randomness flows from one ``np.random.default_rng(seed)``;
ties break on the smallest vertex id.  Handles self-loops (dropped), isolated
vertices (self-matched, placed by the balance pass), ``num_parts == 1`` and
graphs smaller than ``coarsen_to`` (phases 1/3 become no-ops).
"""
from __future__ import annotations

import collections

import numpy as np

Array = np.ndarray


# ---------------------------------------------------------------------------
# weighted CSR graph
# ---------------------------------------------------------------------------

def _edges_to_csr(num_nodes: int, edges: Array
                  ) -> tuple[Array, Array, Array]:
    """(E, 2) undirected edge list -> CSR (xadj, adjncy, adjwgt).

    Self-loops are dropped; duplicate edges accumulate weight (the input
    contract stores each undirected edge once, but the partitioner must not
    depend on it).  Both directions are materialised.
    """
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    e = e[e[:, 0] != e[:, 1]]
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    return _accumulate_csr(num_nodes, src, dst,
                           np.ones(src.shape[0], dtype=np.int64))


def _accumulate_csr(n: int, src: Array, dst: Array, wgt: Array
                    ) -> tuple[Array, Array, Array]:
    """Build CSR from directed (src, dst, wgt) triples, summing parallels."""
    key = src * n + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, wgt = key[order], src[order], dst[order], wgt[order]
    if key.size:
        uniq = np.concatenate([[True], key[1:] != key[:-1]])
        grp = np.cumsum(uniq) - 1
        src, dst = src[uniq], dst[uniq]
        wgt = np.bincount(grp, weights=wgt).astype(np.int64)
    xadj = np.zeros(n + 1, dtype=np.int64)
    np.add.at(xadj, src + 1, 1)
    np.cumsum(xadj, out=xadj)
    return xadj, dst.astype(np.int64), wgt


# ---------------------------------------------------------------------------
# phase 1: coarsening
# ---------------------------------------------------------------------------

def _heavy_edge_matching(xadj: Array, adjncy: Array, adjwgt: Array,
                         vwgt: Array, maxvwgt: int,
                         rng: np.random.Generator) -> tuple[Array, int]:
    """One round of heavy-edge matching.  Returns (cmap, n_coarse):
    ``cmap[v]`` is v's coarse vertex id; matched pairs share an id,
    unmatched (or isolated) vertices keep their own.  A pair whose combined
    weight would exceed ``maxvwgt`` is never matched — METIS' guard against
    coarse vertices too heavy to place inside one part (without it, two
    whole communities can collapse into one unsplittable vertex)."""
    n = xadj.shape[0] - 1
    mate = np.full(n, -1, dtype=np.int64)
    for u in rng.permutation(n):
        if mate[u] >= 0:
            continue
        lo, hi = xadj[u], xadj[u + 1]
        nbrs, wgts = adjncy[lo:hi], adjwgt[lo:hi]
        free = (mate[nbrs] < 0) & (vwgt[u] + vwgt[nbrs] <= maxvwgt)
        best = u
        if free.any():
            nbrs, wgts = nbrs[free], wgts[free]
            top = wgts == wgts.max()
            best = int(nbrs[top].min())          # heaviest edge, lowest id
        mate[u], mate[best] = best, u            # best == u: self-match
    cmap = np.full(n, -1, dtype=np.int64)
    nc = 0
    for u in range(n):
        if cmap[u] < 0:
            cmap[u] = cmap[mate[u]] = nc
            nc += 1
    return cmap, nc


def _contract(xadj: Array, adjncy: Array, adjwgt: Array, vwgt: Array,
              cmap: Array, nc: int
              ) -> tuple[Array, Array, Array, Array]:
    """Contract matched pairs: coarse node weights are sums, parallel coarse
    edges accumulate weight, internal (now self-loop) edges vanish — exactly
    the weight bookkeeping that keeps coarse-level cuts equal to fine-level
    cuts under projection."""
    cvwgt = np.bincount(cmap, weights=vwgt, minlength=nc).astype(np.int64)
    src = np.repeat(np.arange(xadj.shape[0] - 1), np.diff(xadj))
    csrc, cdst = cmap[src], cmap[adjncy]
    keep = csrc != cdst
    cx, ca, cw = _accumulate_csr(nc, csrc[keep], cdst[keep], adjwgt[keep])
    return cx, ca, cw, cvwgt


# ---------------------------------------------------------------------------
# phase 2: initial partition of the coarsest graph
# ---------------------------------------------------------------------------

def _initial_partition(xadj: Array, adjncy: Array, adjwgt: Array,
                       vwgt: Array, num_parts: int, cap_w: float,
                       rng: np.random.Generator) -> Array:
    """Greedy graph growing (METIS' GGGP) under ``cap_w``: each part grows
    from a random unassigned seed by repeatedly absorbing the unassigned
    vertex with the heaviest edge connection to the part (not BFS order —
    the connectivity-greedy choice is what follows heavy coarse edges and
    keeps dense clusters whole).  Stragglers go to the lightest part."""
    n = xadj.shape[0] - 1
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(num_parts, dtype=np.int64)
    order = rng.permutation(n)
    cursor = 0
    neg_inf = -np.inf
    for p in range(num_parts):
        while cursor < n and part[order[cursor]] >= 0:
            cursor += 1
        if cursor >= n:
            break
        conn = np.full(n, neg_inf)               # -inf = not on the frontier
        node = int(order[cursor])
        while sizes[p] + vwgt[node] <= cap_w:
            part[node] = p
            sizes[p] += vwgt[node]
            conn[node] = neg_inf
            lo, hi = xadj[node], xadj[node + 1]
            for v, w in zip(adjncy[lo:hi], adjwgt[lo:hi]):
                if part[v] < 0:
                    conn[v] = max(conn[v], 0.0) + w
            node = int(np.argmax(conn))          # heaviest-connected, min id
            if conn[node] == neg_inf:
                break                            # frontier exhausted
    for node in np.flatnonzero(part < 0):
        p = int(np.argmin(sizes))
        part[node] = p
        sizes[p] += vwgt[node]
    return part


# ---------------------------------------------------------------------------
# phase 3: refinement (used at every level) + strict finest-level balance
# ---------------------------------------------------------------------------

def _refine(xadj: Array, adjncy: Array, adjwgt: Array, vwgt: Array,
            part: Array, num_parts: int, cap_w: float,
            rng: np.random.Generator, passes: int) -> Array:
    """Weighted boundary refinement with a real FM gain-bucket queue.

    The argsort stand-in this replaces re-scored and re-sorted every
    positive-gain candidate each pass and applied the snapshot order
    against drifted gains.  This is the classic Fiduccia–Mattheyses
    discipline instead: per pass every *boundary* vertex files its best
    move into ``buckets[gain]`` (integer edge-weight gains); moves pop
    from the current maximum bucket with lazy invalidation (``filed[u]``
    remembers the gain a vertex was filed under — stale entries are
    skipped or re-filed at their current gain) and the weight cap is
    re-validated at apply time.  Crucially, non-positive-gain moves are
    taken too (each vertex at most once per pass — ``locked``): the pass
    hill-climbs through plateaus and shallow minima, records the running
    cut delta, and afterwards ROLLS BACK to the best prefix of the move
    sequence.  An applied move re-files only the moved vertex's
    neighbours — O(moves·deg) bucket maintenance, and strictly stronger
    search than the positive-gain-only argsort passes (a pass can never
    end worse than it started; it can escape optima the old code was
    stuck in).
    """
    n = xadj.shape[0] - 1
    sizes = np.bincount(part, weights=vwgt, minlength=num_parts
                        ).astype(np.int64)

    def best_move(u: int) -> tuple[int, int]:
        """Highest-gain target for u (may be ≤ 0); -1 if u is interior."""
        lo, hi = xadj[u], xadj[u + 1]
        if lo == hi:
            return -1, 0
        nbr_parts = part[adjncy[lo:hi]]
        cur = int(part[u])
        if (nbr_parts == cur).all():
            return -1, 0                          # interior vertex
        conn = np.bincount(nbr_parts, weights=adjwgt[lo:hi],
                           minlength=num_parts)
        gains = conn - conn[cur]
        gains[cur] = np.iinfo(np.int64).min
        tgt = int(np.argmax(gains))
        return tgt, int(gains[tgt])

    for _ in range(passes):
        buckets: dict[int, collections.deque] = {}
        filed: dict[int, int] = {}                # vertex -> gain filed under
        locked = np.zeros(n, dtype=bool)

        def push(u: int) -> None:
            tgt, g = best_move(u)
            if tgt >= 0:
                buckets.setdefault(g, collections.deque()).append(u)
                filed[u] = g
            else:
                filed.pop(u, None)

        for u in range(n):
            push(u)
        history: list[tuple[int, int, int]] = []  # (u, from, gain)
        cum = best_cum = 0
        best_len = 0
        while buckets:
            g = max(buckets)
            queue = buckets[g]
            if not queue:
                del buckets[g]
                continue
            u = int(queue.popleft())
            if locked[u] or filed.get(u) != g:
                continue                          # stale entry
            tgt, g_now = best_move(u)
            if tgt < 0:
                filed.pop(u, None)
                continue
            if g_now != g:
                push(u)                           # re-file at current gain
                continue
            cur = int(part[u])
            if sizes[tgt] + vwgt[u] > cap_w or sizes[cur] - vwgt[u] <= 0:
                filed.pop(u, None)
                continue
            part[u] = tgt
            sizes[cur] -= vwgt[u]
            sizes[tgt] += vwgt[u]
            locked[u] = True
            filed.pop(u, None)
            history.append((u, cur, g))
            cum += g
            if cum > best_cum:
                best_cum, best_len = cum, len(history)
            for v in adjncy[xadj[u]:xadj[u + 1]]:
                if not locked[v]:
                    push(int(v))
        # roll back to the best prefix of the move sequence (classic FM):
        # the pass keeps only the moves up to the maximum cumulative gain
        for u, src, _ in reversed(history[best_len:]):
            tgt = int(part[u])
            part[u] = src
            sizes[tgt] -= vwgt[u]
            sizes[src] += vwgt[u]
        if best_cum <= 0:
            break
    return part


def _enforce_cap(xadj: Array, adjncy: Array, adjwgt: Array, part: Array,
                 num_parts: int, cap: int) -> Array:
    """Finest level only (unit node weights): evict minimum-cut-loss nodes
    from overfull parts into the least-loaded parts until every size is
    under the strict ``ceil(N / M)`` cap the contract promises."""
    sizes = np.bincount(part, minlength=num_parts).astype(np.int64)
    for p in range(num_parts):
        while sizes[p] > cap:
            members = np.flatnonzero(part == p)
            tgt = int(np.argmin(np.where(np.arange(num_parts) == p,
                                         np.iinfo(np.int64).max, sizes)))
            best_u, best_loss = int(members[0]), None
            for u in members:
                lo, hi = xadj[u], xadj[u + 1]
                conn = np.bincount(part[adjncy[lo:hi]],
                                   weights=adjwgt[lo:hi],
                                   minlength=num_parts)
                loss = int(conn[p] - conn[tgt])
                if best_loss is None or loss < best_loss:
                    best_u, best_loss = int(u), loss
            part[best_u] = tgt
            sizes[p] -= 1
            sizes[tgt] += 1
    return part


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def multilevel_partition(num_nodes: int, edges: Array, num_parts: int,
                         seed: int = 0, refine_iters: int = 4,
                         coarsen_to: int | None = None,
                         balance: float = 1.05) -> Array:
    """Multilevel coarsen→partition→uncoarsen.  Contract-compatible with
    ``repro.core.graph.partition_graph``: (N,) int32, every node assigned,
    sizes ≤ ceil(N / M).

    ``balance`` is the weight-cap slack used *during* coarse-level
    refinement (METIS' imbalance tolerance); the finest level always ends
    with the strict unit-weight cap restored.
    """
    if num_parts <= 0:
        raise ValueError(f"num_parts must be positive, got {num_parts}")
    if num_parts == 1:
        return np.zeros(num_nodes, dtype=np.int32)
    rng = np.random.default_rng(seed)
    cap = int(np.ceil(num_nodes / num_parts))
    if coarsen_to is None:
        # small multiple of the part count: deep enough that one coarse
        # vertex ≈ one dense cluster, so the initial partition assigns
        # clusters wholesale (METIS coarsens to ~O(k) vertices too)
        coarsen_to = max(2 * num_parts, 32)

    xadj, adjncy, adjwgt = _edges_to_csr(num_nodes, edges)
    vwgt = np.ones(num_nodes, dtype=np.int64)

    levels: list[tuple] = []          # (cmap, xadj, adjncy, adjwgt, vwgt)
    while xadj.shape[0] - 1 > coarsen_to:
        cmap, nc = _heavy_edge_matching(xadj, adjncy, adjwgt, vwgt, cap,
                                        rng)
        if nc > 0.95 * (xadj.shape[0] - 1):      # matching stalled
            break
        levels.append((cmap, xadj, adjncy, adjwgt, vwgt))
        xadj, adjncy, adjwgt, vwgt = _contract(
            xadj, adjncy, adjwgt, vwgt, cmap, nc)

    # coarse-level weight cap: the strict node cap with refinement slack,
    # never below the heaviest single coarse vertex (which must fit
    # somewhere for the projection to stay feasible; matching keeps every
    # coarse vertex ≤ cap, so this only widens for degenerate inputs)
    cap_w = max(float(cap) * balance, float(vwgt.max()))
    part = _initial_partition(xadj, adjncy, adjwgt, vwgt, num_parts, cap_w,
                              rng)
    part = _refine(xadj, adjncy, adjwgt, vwgt, part, num_parts, cap_w,
                   rng, refine_iters)

    while levels:
        cmap, xadj, adjncy, adjwgt, vwgt = levels.pop()
        part = part[cmap]                         # project to finer level
        cap_w = max(float(cap) * balance, float(vwgt.max()))
        part = _refine(xadj, adjncy, adjwgt, vwgt, part, num_parts, cap_w,
                       rng, refine_iters)

    part = _enforce_cap(xadj, adjncy, adjwgt, part, num_parts, cap)
    part = _refine(xadj, adjncy, adjwgt, np.ones(num_nodes, np.int64),
                   part, num_parts, float(cap), rng, refine_iters)
    return part.astype(np.int32)
