"""Sharding rules: param/batch/cache pytrees -> PartitionSpec pytrees.

Strategy (standard 2D "megatron + FSDP" layout, expert-parallel MoE):

  * batch/token dims        -> ('pod','data')  (all data axes)
  * expert axis (E, ...)    -> 'model'   (expert parallelism)
  * embedding vocab dim     -> 'model'
  * weight matrices         -> output-feature dim over 'model'; with FSDP
    (params > fsdp_threshold) the input-feature dim additionally over 'data'
  * stacked layer dim (leading, under 'stack') -> never sharded here (the
    layerwise-ADMM trainer shards it over 'model' itself — see
    core/layerwise.py)
  * norms / biases / scalars -> replicated

Axis assignments are applied only when the dim divides evenly; otherwise the
dim stays unsharded (XLA would pad — we prefer predictable layouts).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_THRESHOLD = 8e9    # params; above this, shard input dims over 'data'


def ring_round_coloring(pairs, n_shards: int) -> dict[int, list]:
    """Colour directed shard-to-shard messages into ``ppermute`` rounds.

    ``pairs``: iterable of (src, dst) shard edges (src != dst).  Two
    messages can share a ``lax.ppermute`` round only if the round's pairs
    form a partial permutation (each shard sends to at most one destination
    and receives from at most one source) — exactly a proper *edge
    colouring* of the bipartite multigraph with sender roles on the left,
    receiver roles on the right, and one edge per message.  König's theorem
    says Δ = max(out-degree, in-degree) colours always suffice, and the
    constructive proof (greedy assignment with an alternating-path colour
    flip on conflict) achieves it in O(E·Δ), so the returned schedule is
    round-minimal — the historic ring-offset colouring
    ``(dst - src) mod n_shards`` could burn up to n_shards−1 rounds on a
    Δ=2 skewed topology.  The schedule is static, so it compiles to a
    fixed unrolled sequence of collective-permutes.  Returns
    {colour: sorted [(src, dst), ...]} with colours contiguous from 0.
    """
    edges: list[tuple[int, int]] = []
    for src, dst in pairs:
        src, dst = int(src), int(dst)
        if not (0 <= src < n_shards and 0 <= dst < n_shards):
            raise ValueError(f"shard pair {(src, dst)} out of range "
                             f"for n_shards={n_shards}")
        if src == dst:
            raise ValueError(f"self-edge {(src, dst)} needs no wire")
        edges.append((src, dst))
    # colour -> partner maps per role-node; colour_of keyed by edge index
    # so repeated (src, dst) messages (multigraph) stay well-defined
    send_c: list[dict[int, int]] = [{} for _ in range(n_shards)]
    recv_c: list[dict[int, int]] = [{} for _ in range(n_shards)]
    colour_of: list[int] = [-1] * len(edges)

    def _free(used: dict[int, int]) -> int:
        c = 0
        while c in used:
            c += 1
        return c

    for ei in sorted(range(len(edges)), key=lambda i: edges[i]):
        u, v = edges[ei]
        cu, cv = _free(send_c[u]), _free(recv_c[v])
        if cu != cv:
            # cu is free at sender u but in use at receiver v: flip the
            # alternating cu/cv path starting at v so cu frees up at v too.
            # The path cannot reach u (cu is free there), so after the
            # flip cu is free at both endpoints.
            path: list[int] = []
            node, at_recv, want = v, True, cu
            while True:
                nxt = (recv_c if at_recv else send_c)[node].get(want)
                if nxt is None:
                    break
                path.append(nxt)
                s, d = edges[nxt]
                node = s if at_recv else d
                at_recv = not at_recv
                want = cv if want == cu else cu
            for pe in path:
                s, d = edges[pe]
                del send_c[s][colour_of[pe]]
                del recv_c[d][colour_of[pe]]
            for pe in path:
                s, d = edges[pe]
                new = cv if colour_of[pe] == cu else cu
                colour_of[pe] = new
                send_c[s][new] = pe
                recv_c[d][new] = pe
        colour_of[ei] = cu
        send_c[u][cu] = ei
        recv_c[v][cu] = ei

    rounds: dict[int, list] = {}
    for ei, (u, v) in enumerate(edges):
        rounds.setdefault(colour_of[ei], []).append((u, v))
    for colour, members in rounds.items():
        members.sort()
        if len(set(s for s, _ in members)) != len(members) or \
                len(set(d for _, d in members)) != len(members):
            raise ValueError(f"round {colour} is not a partial permutation: "
                             f"{members}")
    return dict(sorted(rounds.items()))


class CommunityBatchSampler:
    """Seeded, balance-aware random multi-cluster batches (Cluster-GCN).

    Sampling granularity is the SHARD — a shard's k communities always
    travel together (they share a device, a packed state plane and an
    exchange-plan slot table, so sampling below shard granularity would
    fragment the compiled program without saving resident bytes).  With
    one community per shard (the benchmark deployment) this is exact
    per-community sampling, the paper-faithful regime.

    Each *cycle* partitions all ``n_shards`` shards into
    ``num_batches = min(n_shards, round(1/batch_fraction))`` batches, so
    every shard is sampled exactly once per cycle — staleness is bounded
    by ``num_batches - 1`` rounds by construction.  Batches are
    balance-aware: shards are shuffled (seeded per cycle), stably sorted
    heaviest-first by ``weights`` (Σ bucket rows — the resident/compute
    load), and greedily dropped into the lightest batch, so a size-skewed
    partition does not stack its giants into one round.  Deterministic
    for a fixed ``seed``: batch ``t`` is a pure function of (seed, t).
    """

    def __init__(self, n_shards: int, batch_fraction: float, seed: int = 0,
                 weights: "np.ndarray | None" = None):
        if not 0.0 < batch_fraction <= 1.0:
            raise ValueError(f"batch_fraction must be in (0, 1], got "
                             f"{batch_fraction!r}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.batch_fraction = float(batch_fraction)
        self.num_batches = min(self.n_shards,
                               max(1, int(round(1.0 / batch_fraction))))
        self.seed = int(seed)
        if weights is None:
            w = np.ones(self.n_shards, dtype=np.float64)
        else:
            w = np.asarray(weights, dtype=np.float64)
            if w.shape != (self.n_shards,):
                raise ValueError(f"weights must be ({self.n_shards},), "
                                 f"got {w.shape}")
        self.weights = np.maximum(w, 1.0)
        self._cycles: dict[int, tuple[tuple[int, ...], ...]] = {}

    def cycle(self, c: int) -> tuple[tuple[int, ...], ...]:
        """The ``num_batches`` shard batches of cycle ``c`` (memoised)."""
        if c not in self._cycles:
            rng = np.random.default_rng((self.seed, int(c)))
            order = rng.permutation(self.n_shards)
            # heaviest first, ties in the cycle's random order (stable)
            order = order[np.argsort(-self.weights[order], kind="stable")]
            batches: list[list[int]] = [[] for _ in range(self.num_batches)]
            loads = np.zeros(self.num_batches)
            for s in order:
                b = int(np.argmin(loads))
                batches[b].append(int(s))
                loads[b] += self.weights[s]
            self._cycles[c] = tuple(tuple(sorted(b)) for b in batches)
        return self._cycles[c]

    def batch(self, t: int) -> tuple[int, ...]:
        """Sampled shard ids of round ``t`` (sorted, non-empty)."""
        c, i = divmod(int(t), self.num_batches)
        return self.cycle(c)[i]


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def _assign(shape, wants, mesh):
    """wants: list of (dim_idx, axis_name) in priority order; returns a
    PartitionSpec assigning each axis at most once, only if it divides."""
    spec: list[Optional[str]] = [None] * len(shape)
    used: set[str] = set()
    for dim, axis in wants:
        if axis in used or axis not in mesh.axis_names:
            continue
        if dim < len(shape) and shape[dim] % _axis_size(mesh, axis) == 0 \
                and spec[dim] is None and shape[dim] > 1:
            spec[dim] = axis
            used.add(axis)
    return P(*spec)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shapes: Any) -> Any:
    """params_shapes: pytree of ShapeDtypeStruct (or arrays)."""
    fsdp = cfg.param_count() > FSDP_THRESHOLD

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        stacked = "stack/" in name or name.startswith("stack")
        off = 1 if stacked else 0        # leading layer-stack dim

        if nd - off <= 1:                # norms, biases, scalars, lam
            return P(*([None] * nd))

        # embedding: (V, D) table / (D, V) unembed
        if "embedding" in name:
            if "table" in name:
                wants = [(0, "model")] + ([(1, "data")] if fsdp else [])
            else:
                wants = [(1, "model")] + ([(0, "data")] if fsdp else [])
            return _assign(shape, wants, mesh)

        # MoE experts: (L, E, d, f) -> E over model, d over data (fsdp)
        if any(k in name for k in ("w_gate", "w_up", "w_down")) \
                and nd - off == 3:
            wants = [(off, "model")] + ([(off + 1, "data")] if fsdp else [])
            return _assign(shape, wants, mesh)
        if "router" in name:
            return P(*([None] * nd))

        # RG-LRU block-diagonal gates (L, NB, bs, bs): replicate (small)
        if "gate_a" in name or "gate_x" in name:
            return P(*([None] * nd))
        # depthwise conv (L, k, W): shard channel dim over model
        if "/conv/" in name or name.endswith("conv/w") or "conv/b" in name:
            wants = [(nd - 1, "model")]
            return _assign(shape, wants, mesh)

        # generic 2D weight (L, in, out): output dim over 'model',
        # input dim over 'data' under FSDP. "down"/"out"/"o" projections
        # have their *input* as the parallel dim -> flip so the contraction
        # stays local after the up-projection sharding.
        is_reduce_in = any(name.endswith(s) or f"/{s}" in name.split("/")[-1]
                           for s in ("down", "out", "o", "out_proj"))
        if nd - off == 2:
            if is_reduce_in:
                wants = [(off, "model")] + ([(off + 1, "data")] if fsdp else [])
            else:
                wants = [(off + 1, "model")] + ([(off, "data")] if fsdp else [])
            return _assign(shape, wants, mesh)

        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, params_shapes: Any,
                    opt_shapes: Any) -> Any:
    """Adam moments mirror param sharding; scalars replicated."""
    pspecs = param_specs(cfg, mesh, params_shapes)

    if isinstance(opt_shapes, dict) and "m" in opt_shapes:
        return {"m": pspecs, "v": pspecs,
                "t": P()}
    # stateless optimizers: ()
    return jax.tree.map(lambda _: P(), opt_shapes)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shapes: Any) -> Any:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def rule(path, leaf):
        shape = leaf.shape
        b = shape[0]
        total_dp = int(np.prod([_axis_size(mesh, a) for a in dp]))
        spec: list = [None] * len(shape)
        if b % total_dp == 0 and b >= total_dp:
            spec[0] = dp
        elif b % _axis_size(mesh, "data") == 0 and b >= _axis_size(mesh, "data"):
            spec[0] = "data"
        # embeddings inputs (B, S, D): D over model
        if len(shape) == 3 and shape[-1] == cfg.d_model:
            spec[-1] = None
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shapes: Any) -> Any:
    """Decode caches: (L, B, S, H, hd) etc.  Batch over data axes when it
    divides; otherwise (B=1 long-context) shard the sequence/window dim over
    'data'; heads/state dims over 'model' when divisible."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total_dp = int(np.prod([_axis_size(mesh, a) for a in dp]))

    def rule(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd <= 1:
            return P(*([None] * nd))
        spec: list = [None] * nd
        # dim 0 is the stacked layer dim; dim 1 the batch
        if nd >= 2 and shape[1] % total_dp == 0 and shape[1] >= total_dp:
            spec[1] = dp
        elif nd >= 3 and shape[1] == 1:
            # B=1: sequence parallelism over 'data'
            if shape[2] % _axis_size(mesh, "data") == 0 and shape[2] > 1:
                spec[2] = "data"
        # heads / channel dims over 'model' (k/v: dim 3; ssm h: dim 2)
        for d in range(nd - 1, 1, -1):
            if spec[d] is None and shape[d] % _axis_size(mesh, "model") == 0 \
                    and shape[d] >= _axis_size(mesh, "model") and d != 2:
                spec[d] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
