from repro.sharding.multilevel import multilevel_partition  # noqa: F401
from repro.sharding.partition import (  # noqa: F401
    batch_specs, cache_specs, param_specs)
