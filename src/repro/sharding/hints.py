"""Activation-sharding hints (the §Perf optimizations).

The baseline relies on XLA SPMD propagation from the parameter shardings.
That leaves two expensive reshardings in the lowered HLO (EXPERIMENTS.md
§Perf):

  1. attention: when num_heads % model_axis != 0, the (B,S,H*hd) ->
     (B,S,H,hd) reshape breaks propagation and XLA moves the quadratic
     score buffers through 'model'-axis collectives.  Hint: shard the
     *query sequence* over 'model' (context parallelism) — scores become
     local; only the small GQA K/V is gathered.
  2. when heads divide evenly, pin head sharding explicitly so the scores
     never leave their shard.

Enabled via ``with sharding_hints(mesh):`` (the optimized dry-run path and
launchers); a no-op when inactive, so model code stays backend-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def sharding_hints(mesh: Mesh, moe_a2a: bool = False):
    """``moe_a2a`` additionally routes MoE FFNs through the explicit
    expert-parallel all-to-all dispatch (models/moe.py::apply_moe_a2a)."""
    prev = getattr(_state, "mesh", None)
    prev_a2a = getattr(_state, "moe_a2a", False)
    _state.mesh = mesh
    _state.moe_a2a = moe_a2a
    try:
        yield
    finally:
        _state.mesh = prev
        _state.moe_a2a = prev_a2a


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def moe_a2a_enabled() -> bool:
    return bool(getattr(_state, "moe_a2a", False))


def _manual_axes() -> frozenset:
    """Mesh axes that are Manual in the current trace (inside shard_map):
    with_sharding_constraint may not mention them."""
    try:
        import jax.sharding as jsh
        am = jsh.get_abstract_mesh()
        return frozenset(
            n for n, t in zip(getattr(am, "axis_names", ()),
                              getattr(am, "axis_types", ()))
            if t == jsh.AxisType.Manual)
    except Exception:
        return frozenset()


def _dp_axes(mesh) -> tuple[str, ...]:
    manual = _manual_axes()
    return tuple(a for a in mesh.axis_names
                 if a in ("pod", "data") and a not in manual)


def hint_qkv(q: jax.Array, k: jax.Array, v: jax.Array):
    """Constrain attention activations (B, S, H, hd) before the score
    matmul.  Head sharding when H divides the model axis; otherwise
    sequence (context) parallelism on the query."""
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or "model" in _manual_axes():
        return q, k, v
    msz = mesh.shape["model"]
    dp = _dp_axes(mesh)
    bq = dp if dp and _div(q.shape[0], mesh, dp) else None

    def wsc(x, spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    if q.shape[2] % msz == 0 and k.shape[2] % msz == 0:
        q = wsc(q, P(bq, None, "model", None))
        k = wsc(k, P(bq, None, "model", None))
        v = wsc(v, P(bq, None, "model", None))
    elif q.shape[1] % msz == 0:
        # context parallelism: q rows sharded; k/v replicated over 'model'
        q = wsc(q, P(bq, "model", None, None))
        k = wsc(k, P(bq, None, None, None))
        v = wsc(v, P(bq, None, None, None))
    return q, k, v


def hint_residual(x: jax.Array):
    """Sequence-parallel residual stream (Korthikanti et al.): (B, S, D)
    batch over the data axes and sequence over 'model' between blocks —
    norms/elementwise run 1/nm-sharded, and the layout matches both the
    context-parallel attention queries and the token-split MoE dispatch
    (no boundary resharding)."""
    mesh = active_mesh()
    if mesh is None or x.ndim != 3:
        return x
    manual = _manual_axes()
    dp = _dp_axes(mesh)
    bspec = dp if dp and _div(x.shape[0], mesh, dp) else None
    seq = "model" if ("model" in mesh.axis_names
                      and "model" not in manual
                      and x.shape[1] % mesh.shape["model"] == 0) else None
    if bspec is None and seq is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, seq, None)))


def hint_moe_buffers(buf_in: jax.Array, buf_out: jax.Array):
    """Expert-parallel MoE: pin the (E·C, D) dispatch/return buffers to the
    'model' (expert) axis so the scatter lowers to an all-to-all instead of
    a replicated scatter + all-reduce."""
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or "model" in _manual_axes():
        return buf_in, buf_out
    msz = mesh.shape["model"]
    if buf_in.shape[0] % msz or buf_out.shape[0] % msz:
        return buf_in, buf_out

    def wsc(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P("model", *([None] * (x.ndim - 1)))))

    return wsc(buf_in), wsc(buf_out)


def hint_tokens(x: jax.Array):
    """Keep flattened token activations (T, D) sharded over the data axes."""
    mesh = active_mesh()
    if mesh is None:
        return x
    dp = _dp_axes(mesh)
    if not dp or not _div(x.shape[0], mesh, dp):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))


def _div(dim: int, mesh, axes) -> bool:
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return total > 0 and dim % total == 0 and dim >= total
