from repro.data.pipeline import TokenPipeline  # noqa: F401
from repro.data.synthetic import synthetic_token_batches  # noqa: F401
