"""Sharded host→device data pipeline.

Double-buffered iterator that places each global batch according to the
mesh's data axes (jax.device_put with a NamedSharding), prefetching the
next host batch while the current step runs — the standard input-pipeline
shape for a pjit training loop.
"""
from __future__ import annotations

import collections
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class TokenPipeline:
    def __init__(self, source: Iterator[dict], mesh: Optional[Mesh] = None,
                 batch_axes: tuple[str, ...] = ("data",),
                 prefetch: int = 2):
        self.source = source
        self.mesh = mesh
        self.batch_axes = tuple(a for a in batch_axes
                                if mesh is not None
                                and a in mesh.axis_names)
        self.prefetch = prefetch
        self._buf: collections.deque = collections.deque()
        self._lock = threading.Lock()

    def _place(self, batch: dict) -> dict:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        spec = P(self.batch_axes if self.batch_axes else None)

        def put(v):
            sh = NamedSharding(self.mesh,
                               P(*((spec[0],) + (None,) * (v.ndim - 1))))
            return jax.device_put(v, sh)

        return {k: put(v) for k, v in batch.items()}

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        with self._lock:
            while len(self._buf) < self.prefetch:
                self._buf.append(self._place(next(self.source)))
            return self._buf.popleft()
