"""Synthetic data sources.

Graphs (SBM matched to the paper's datasets) live in ``repro.core.graph``;
this module provides token streams for the transformer substrate: a mixture
of Zipf-distributed unigrams and deterministic skip-gram patterns so that a
model can actually reduce loss by learning structure (useful for the
end-to-end training example, where a flat random stream would be
information-free).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def synthetic_token_batches(vocab_size: int, batch: int, seq_len: int,
                            seed: int = 0,
                            pattern_period: int = 8) -> Iterator[dict]:
    """Yields {'tokens', 'targets'} int32 arrays forever.

    Structure: token[t] depends on token[t - pattern_period] (copy with a
    fixed offset) half the time, Zipf noise otherwise — a learnable
    long-range dependency with tunable difficulty.
    """
    rng = np.random.default_rng(seed)
    zipf_p = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
    zipf_p /= zipf_p.sum()
    offset = 17 % vocab_size
    while True:
        toks = rng.choice(vocab_size, size=(batch, seq_len + 1),
                          p=zipf_p).astype(np.int32)
        for t in range(pattern_period, seq_len + 1):
            copy_mask = rng.random(batch) < 0.5
            toks[copy_mask, t] = (toks[copy_mask, t - pattern_period]
                                  + offset) % vocab_size
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
