"""Moonlight-16B-A3B (moonshot) [hf:moonshotai/Moonlight-16B-A3B].

Assigned: 48 layers, d_model 2048, 16 heads (kv=16, i.e. MHA), MoE with 64
experts top-6, expert width 1408, vocab 163840.  The HF card uses the
DeepSeek-V3 topology (2 shared experts, fine-grained routing); we follow the
assigned head/kv counts exactly and the card's shared-expert count.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        arch_type="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=11264,
        vocab_size=163840,
        mlp="swiglu",
        norm="rmsnorm",
        rope_theta=50000.0,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                      d_ff_expert=1408, first_dense_layers=1,
                      dense_d_ff=11264),
        grad_accum=4,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-reduced",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        mlp="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=2,
                      d_ff_expert=128, first_dense_layers=1, dense_d_ff=512),
        dtype="float32",
        source="hf:moonshotai/Moonlight-16B-A3B (reduced)",
    )
