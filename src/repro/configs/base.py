"""Model/config dataclasses for the assigned architectures.

Every architecture file in this package instantiates ``ModelConfig`` with the
exact assigned numbers (source paper / model card cited in its docstring) and
provides a ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

ArchType = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0            # per-expert FFN width
    first_dense_layers: int = 0     # leading layers with dense FFN
    dense_d_ff: int = 0             # width of those dense FFNs
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD mixer (arXiv:2405.21060)."""
    d_state: int = 128
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RG-LRU + local attention (RecurrentGemma/Griffin, arXiv:2402.19427)."""
    pattern: tuple[str, ...] = ("rglru", "rglru", "attn")
    lru_width: int = 0              # 0 => d_model
    local_window: int = 2048
    conv_kernel: int = 4
    lru_c: float = 8.0


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend (audio/vision): input_specs() provides
    precomputed frame/patch embeddings of this shape (the one allowed stub)."""
    kind: Literal["audio", "vision"] = "vision"
    num_embeddings: int = 256       # patches / frames fed to the backbone
    embed_dim: int = 0              # 0 => d_model (projector output)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads
    # stack / variant switches
    mlp: Literal["swiglu", "geglu", "relu2", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # long-context attention window
    is_encoder_decoder: bool = False
    num_decoder_layers: int = 0            # enc-dec only
    # substructure configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: Optional[FrontendConfig] = None
    # MTP (multi-token prediction, DeepSeek-V3): one extra predict block
    mtp_depth: int = 0
    # training
    dtype: str = "bfloat16"
    optimizer: str = "adam"         # 'sgd' for the largest archs (see DESIGN)
    learning_rate: float = 3e-4
    remat: bool = True              # activation checkpointing per layer
    grad_accum: int = 1             # microbatch accumulation in train_step
    # citation for the exact numbers above
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0 or \
            self.num_kv_heads == 0
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.num_experts
        if self.arch_type == "ssm":
            assert self.ssm is not None
        if self.is_encoder_decoder:
            assert self.num_decoder_layers > 0

    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6·N·D roofline)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed)."""
        return _param_count(self, active_only=True)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * d_ff


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = cfg.d_model * m.q_lora_rank            # q down
        p += m.q_lora_rank * cfg.num_heads * qk_hd  # q up
        p += cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down
        p += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim
                                               + m.v_head_dim)    # kv up
        p += cfg.num_heads * m.v_head_dim * cfg.d_model            # o proj
        return p
    q = cfg.d_model * cfg.num_heads * hd
    kv = 2 * cfg.d_model * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * cfg.d_model
    return q + kv + o


def _layer_params(cfg: ModelConfig, layer_idx: int) -> int:
    """Per-layer params for roofline bookkeeping (norms ignored, <0.1%)."""
    if cfg.arch_type == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        n_heads = d_in // s.head_dim
        proj_in = cfg.d_model * (2 * d_in + 2 * s.n_groups * s.d_state
                                 + n_heads)
        return proj_in + d_in * cfg.d_model + s.conv_kernel * (
            d_in + 2 * s.n_groups * s.d_state)
    if cfg.hybrid is not None:
        kind = cfg.hybrid.pattern[layer_idx % len(cfg.hybrid.pattern)]
        w = cfg.hybrid.lru_width or cfg.d_model
        if kind == "rglru":
            mix = 2 * cfg.d_model * w + w * cfg.d_model + \
                cfg.hybrid.conv_kernel * w + 2 * w * w // 8  # block-diag gates
        else:
            mix = _attn_params(cfg)
        return mix + _ffn_params(cfg, cfg.d_ff)
    p = _attn_params(cfg)
    if cfg.moe is not None and layer_idx >= cfg.moe.first_dense_layers:
        moe = cfg.moe
        p += moe.num_experts * _ffn_params(cfg, moe.d_ff_expert)
        p += moe.num_shared_experts * _ffn_params(cfg, moe.d_ff_expert)
        p += cfg.d_model * moe.num_experts      # router
    elif cfg.moe is not None:
        p += _ffn_params(cfg, cfg.moe.dense_d_ff or cfg.d_ff)
    else:
        p += _ffn_params(cfg, cfg.d_ff)
    return p


def _layer_params_active(cfg: ModelConfig, layer_idx: int) -> int:
    if cfg.moe is None or layer_idx < cfg.moe.first_dense_layers:
        return _layer_params(cfg, layer_idx)
    moe = cfg.moe
    p = _attn_params(cfg)
    p += (moe.top_k + moe.num_shared_experts) * _ffn_params(
        cfg, moe.d_ff_expert)
    p += cfg.d_model * moe.num_experts
    return p


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    fn = _layer_params_active if active_only else _layer_params
    total = sum(fn(cfg, i) for i in range(cfg.num_layers))
    if cfg.is_encoder_decoder:
        # decoder layers: self-attn + cross-attn + ffn
        dec = sum(fn(cfg, i) + _attn_params(cfg)
                  for i in range(cfg.num_decoder_layers))
        total += dec
    emb = cfg.vocab_size * cfg.d_model
    total += emb if cfg.tie_embeddings else 2 * emb
    return total
