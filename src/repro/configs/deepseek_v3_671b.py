"""DeepSeek-V3 671B [arXiv:2412.19437].

61 layers, d_model 7168, 128 heads with MLA (the assigned 'GQA kv=128' is
realized as MLA per the source paper), MoE: 1 shared + 256 routed experts
top-8 with expert width 2048, first 3 layers dense (d_ff 18432), MTP depth 1,
vocab 129280.  Optimizer is SGD for the dry-run: Adam state for 671B params
does not fit 256 × 16 GB (DESIGN.md §5).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=18432,
        vocab_size=129280,
        mlp="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                      d_ff_expert=2048, first_dense_layers=3,
                      dense_d_ff=18432),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        mtp_depth=1,
        optimizer="sgd",
        grad_accum=8,
        source="arXiv:2412.19437",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-reduced",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        mlp="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_ff_expert=128, first_dense_layers=1, dense_d_ff=512),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32),
        mtp_depth=1,
        dtype="float32",
        optimizer="sgd",
        source="arXiv:2412.19437 (reduced)",
    )
