from repro.configs.base import ModelConfig  # noqa: F401
from repro.configs.registry import get_config, list_archs  # noqa: F401
from repro.configs.shapes import INPUT_SHAPES, InputShape  # noqa: F401
