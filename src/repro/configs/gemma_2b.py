"""Gemma 2B [arXiv:2403.08295].

18 layers, d_model 2048, 8 heads MQA (kv=1) with head_dim 256, GeGLU MLP
d_ff 16384, vocab 256000, tied embeddings.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        arch_type="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp="geglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        tie_embeddings=True,
        grad_accum=4,
        source="arXiv:2403.08295",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        mlp="geglu",
        tie_embeddings=True,
        dtype="float32",
        source="arXiv:2403.08295 (reduced)",
    )
