"""Nemotron-4 15B [arXiv:2402.16819].

32 layers, d_model 6144, 48 heads GQA kv=8, d_ff 24576 with squared-ReLU
(non-gated) MLP, vocab 256000, RoPE, no bias.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=256000,
        mlp="relu2",
        norm="layernorm",
        rope_theta=10000.0,
        grad_accum=4,
        source="arXiv:2402.16819",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        mlp="relu2",
        norm="layernorm",
        dtype="float32",
        source="arXiv:2402.16819 (reduced)",
    )
