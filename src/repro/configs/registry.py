"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib


_ARCH_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "nemotron-4-15b": "nemotron_4_15b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-1.3b": "mamba2_1_3b",
    "gemma-2b": "gemma_2b",
    "qwen2-7b": "qwen2_7b",
    "internvl2-2b": "internvl2_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gcn-paper": "gcn_paper",
}


def list_archs() -> list[str]:
    return [a for a in _ARCH_MODULES if a != "gcn-paper"]


def get_config(arch: str, reduced: bool = False):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.reduced() if reduced else mod.config()
