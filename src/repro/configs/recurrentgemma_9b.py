"""RecurrentGemma 9B [arXiv:2402.19427].

38 layers, pattern (RG-LRU, RG-LRU, local-attn) 1:2 — 12 full periods + 2
trailing recurrent blocks; d_model 4096, 16 heads MQA (kv=1, head_dim 256)
for the local-attention blocks (window 2048), GeGLU d_ff 12288,
lru_width 4096, vocab 256000.  Sub-quadratic (bounded window + recurrent
state) — runs long_500k natively.
"""
from repro.configs.base import HybridConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        mlp="geglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        tie_embeddings=True,
        hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"),
                            lru_width=4096, local_window=2048,
                            conv_kernel=4, lru_c=8.0),
        grad_accum=4,
        source="arXiv:2402.19427",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-reduced",
        arch_type="hybrid",
        num_layers=5,          # 1 period + 2 tail rglru blocks
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        mlp="geglu",
        tie_embeddings=True,
        hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"),
                            lru_width=256, local_window=64,
                            conv_kernel=4, lru_c=8.0),
        dtype="float32",
        source="arXiv:2402.19427 (reduced)",
    )
