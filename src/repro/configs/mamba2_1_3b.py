"""Mamba-2 1.3B [arXiv:2405.21060].

48 layers (attention-free), d_model 2048, SSD mixer with d_state 128,
head_dim 64, expand 2, vocab 50280.  Sub-quadratic by construction — runs
long_500k natively.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        arch_type="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        norm="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                      conv_kernel=4, chunk_size=256),
        grad_accum=2,
        source="arXiv:2405.21060",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-reduced",
        arch_type="ssm",
        num_layers=2,
        d_model=256,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=32, head_dim=32, expand=2, n_groups=1,
                      conv_kernel=4, chunk_size=32),
        dtype="float32",
        source="arXiv:2405.21060 (reduced)",
    )
