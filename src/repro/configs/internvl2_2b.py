"""InternVL2-2B language backbone (InternLM2-1.8B) [arXiv:2404.16821].

24 layers, d_model 2048, 16 heads GQA kv=8, SwiGLU d_ff 8192, vocab 92553.
The InternViT vision encoder + MLP projector are STUBBED per the
assignment: input_specs() provides 256 projected patch embeddings
(B, 256, d_model) prepended to the text tokens.
"""
from repro.configs.base import FrontendConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        arch_type="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        mlp="swiglu",
        norm="rmsnorm",
        rope_theta=1000000.0,
        frontend=FrontendConfig(kind="vision", num_embeddings=256),
        grad_accum=2,
        source="arXiv:2404.16821",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-reduced",
        arch_type="vlm",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        mlp="swiglu",
        frontend=FrontendConfig(kind="vision", num_embeddings=16),
        dtype="float32",
        source="arXiv:2404.16821 (reduced)",
    )
