"""Qwen2-7B [arXiv:2407.10671].

28 layers, d_model 3584, 28 heads GQA kv=4 (head_dim 128), SwiGLU d_ff 18944,
QKV bias, vocab 152064.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        arch_type="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        mlp="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope_theta=1000000.0,
        grad_accum=4,
        source="arXiv:2407.10671",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-reduced",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        mlp="swiglu",
        qkv_bias=True,
        dtype="float32",
        source="arXiv:2407.10671 (reduced)",
    )
