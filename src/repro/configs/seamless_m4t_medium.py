"""SeamlessM4T-medium text decoder backbone [arXiv:2308.11596].

Assigned: 12 layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 256206.  Encoder-decoder: 12 encoder + 12 decoder layers (the T2TT
component of the medium card).  The speech frontend (mel + conformer
feature extractor) is STUBBED per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model) for the encoder.
"""
from repro.configs.base import FrontendConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        arch_type="audio",
        num_layers=12,
        num_decoder_layers=12,
        is_encoder_decoder=True,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        mlp="gelu",
        norm="layernorm",
        rope_theta=10000.0,
        frontend=FrontendConfig(kind="audio", num_embeddings=1536),
        grad_accum=2,
        source="arXiv:2308.11596",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-reduced",
        arch_type="audio",
        num_layers=2,
        num_decoder_layers=2,
        is_encoder_decoder=True,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        mlp="gelu",
        norm="layernorm",
        frontend=FrontendConfig(kind="audio", num_embeddings=64),
        dtype="float32",
        source="arXiv:2308.11596 (reduced)",
    )
