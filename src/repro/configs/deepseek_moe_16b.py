"""DeepSeekMoE 16B [arXiv:2401.06066].

28 layers, d_model 2048, 16 heads MHA (kv=16), fine-grained MoE: 64 routed
experts top-6 + 2 shared experts of width 1408; first layer dense with
d_ff 10944; vocab 102400.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        arch_type="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=10944,
        vocab_size=102400,
        mlp="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                      d_ff_expert=1408, first_dense_layers=1,
                      dense_d_ff=10944),
        grad_accum=4,
        source="arXiv:2401.06066",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-reduced",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        mlp="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=2,
                      d_ff_expert=128, first_dense_layers=1, dense_d_ff=512),
        dtype="float32",
        source="arXiv:2401.06066 (reduced)",
    )
