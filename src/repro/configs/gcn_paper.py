"""The paper's own GCN configuration (§4.1): 2-layer GCN, 1000 hidden units,
ReLU, cross-entropy, ν = ρ = 1e-3 (Computers) / 1e-4 (Photo)."""
from repro.core.gcn import GCNConfig
from repro.core.subproblems import ADMMConfig


def config(dataset: str = "amazon_computers"):
    feats = {"amazon_computers": 767, "amazon_photo": 745,
             "amazon_computers_mini": 767, "amazon_photo_mini": 745}[dataset]
    classes = {"amazon_computers": 10, "amazon_photo": 8,
               "amazon_computers_mini": 10, "amazon_photo_mini": 8}[dataset]
    hyper = 1e-3 if "computers" in dataset else 1e-4
    return (GCNConfig(layer_dims=(feats, 1000, classes)),
            ADMMConfig(nu=hyper, rho=hyper))


def reduced(dataset: str = "amazon_photo_mini"):
    cfg, admm = config(dataset)
    return GCNConfig(layer_dims=(cfg.layer_dims[0], 64,
                                 cfg.layer_dims[-1])), admm
