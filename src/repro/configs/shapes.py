"""The four assigned input shapes and what step each one lowers."""
from __future__ import annotations

import dataclasses
from typing import Literal

StepKind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    step: StepKind


INPUT_SHAPES: dict[str, InputShape] = {
    # training step (forward + backward + optimizer)
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    # forward-only prefill producing the KV cache / final state
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    # ONE new token against a seq_len cache
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    # long-context decode: sub-quadratic attention required (SSM/hybrid
    # native; dense archs run their sliding-window variant — DESIGN.md)
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
