"""Pytree checkpointing: npz payload + JSON treedef/sharding metadata.

``save`` gathers shards to host (fine at example scale; a production TPU
deployment would write per-host shards — the metadata format already
records the PartitionSpec per leaf so that restore can re-place arrays on
a mesh of a different size).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def save(directory: str | Path, tree: Any, step: int = 0) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload, meta = {}, {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i}"
        payload[key] = arr
        sharding = getattr(leaf, "sharding", None)
        spec = list(sharding.spec) if isinstance(sharding, NamedSharding) \
            else None
        meta["leaves"].append({
            "key": key, "path": _path_str(path),
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "spec": json.loads(json.dumps(spec, default=str)),
        })
    out = directory / f"ckpt_{step:08d}"
    np.savez(str(out) + ".npz", **payload)
    (directory / f"ckpt_{step:08d}.json").write_text(json.dumps(meta))
    return out


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    steps = sorted(int(p.stem.split("_")[1])
                   for p in directory.glob("ckpt_*.json"))
    return steps[-1] if steps else None


def restore(directory: str | Path, tree_like: Any,
            step: Optional[int] = None, mesh: Optional[Mesh] = None) -> Any:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    data = np.load(directory / f"ckpt_{step:08d}.npz")
    meta = json.loads((directory / f"ckpt_{step:08d}.json").read_text())
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
        tree_like)
    by_path = {m["path"]: m for m in meta["leaves"]}
    new_leaves = []
    for path, leaf in leaves_with_paths:
        m = by_path[_path_str(path)]
        arr = data[m["key"]]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch at {m['path']}: "
                             f"{arr.shape} vs {leaf.shape}")
        if mesh is not None and m["spec"] is not None:
            spec = P(*[tuple(s) if isinstance(s, list) else s
                       for s in m["spec"]])
            arr = jax.device_put(arr, NamedSharding(mesh, spec))
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
