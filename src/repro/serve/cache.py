"""Fixed-capacity caches for the serving engine.

Two structures back ``serve.CommunityServer``:

  * ``LRUCache`` — a fixed-capacity ordered map with optional
    frequency-based ("Zipf-aware") admission: under a heavy-tailed request
    stream plain LRU lets a burst of cold keys evict the hot head, so the
    cache tracks an aged frequency sketch (``FrequencySketch``, the
    TinyLFU idea) and refuses to evict a victim that is strictly hotter
    than the candidate.
  * ``CacheStats`` — the counters the benchmark and the CI guards report
    (hit rate, evictions, admission rejections, invalidations).

Host-side and value-agnostic: the engine stores device arrays, the tests
store ints.  Invariants (pinned by tests/test_serve_cache.py and the
hypothesis suite in tests/test_property.py): size never exceeds capacity,
``get`` refreshes recency, eviction takes the least-recently-used key,
and admission never swaps a strictly hotter victim for a colder candidate.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    rejections: int = 0       # inserts refused by admission
    invalidations: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "rejections": self.rejections,
                "invalidations": self.invalidations,
                "hit_rate": round(self.hit_rate, 4)}

    def reset(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.rejections = self.invalidations = 0


class FrequencySketch:
    """Aged access-frequency estimator (TinyLFU-style).

    Exact counts with periodic halving: after every ``sample`` touches all
    counts are halved (zeros dropped), so estimates track the *recent*
    popularity distribution rather than all of history — a key that was
    hot an hour ago decays instead of squatting on its admission
    privilege.
    """

    def __init__(self, sample: int = 1024):
        if sample <= 0:
            raise ValueError(f"sample must be positive, got {sample}")
        self.sample = int(sample)
        self._counts: dict[Hashable, int] = {}
        self._touches = 0

    def touch(self, key: Hashable) -> None:
        self._counts[key] = self._counts.get(key, 0) + 1
        self._touches += 1
        if self._touches >= self.sample:
            self._age()

    def _age(self) -> None:
        self._counts = {k: c // 2 for k, c in self._counts.items()
                        if c // 2 > 0}
        self._touches = 0

    def estimate(self, key: Hashable) -> int:
        return self._counts.get(key, 0)


class LRUCache:
    """Fixed-capacity LRU map with optional frequency admission.

    ``admission="lru"`` is plain LRU (every insert admitted, LRU key
    evicted).  ``admission="zipf"`` consults the frequency sketch on a
    full cache: the candidate is admitted only if its estimated frequency
    is at least the LRU victim's — under a Zipf stream this keeps the hot
    head resident through bursts of one-off cold keys.  ``capacity=0``
    disables the cache (every get misses, every put is refused) — the
    engine's cache-disabled baseline.
    """

    def __init__(self, capacity: int, admission: str = "lru",
                 sample: int = 1024):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        if admission not in ("lru", "zipf"):
            raise ValueError(f"unknown admission {admission!r}; "
                             f"expected 'lru' or 'zipf'")
        self.capacity = int(capacity)
        self.admission = admission
        self.stats = CacheStats()
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self._sketch = FrequencySketch(sample) if admission == "zipf" \
            else None

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        """Presence probe — touches neither recency nor stats."""
        return key in self._data

    def keys(self) -> list:
        """Keys in eviction order (least recently used first)."""
        return list(self._data)

    def get(self, key: Hashable) -> Optional[Any]:
        """Lookup; refreshes recency and feeds the admission sketch."""
        if self._sketch is not None:
            self._sketch.touch(key)
        if key in self._data:
            self._data.move_to_end(key)
            self.stats.hits += 1
            return self._data[key]
        self.stats.misses += 1
        return None

    def put(self, key: Hashable, value: Any) -> bool:
        """Insert/overwrite; returns True when the entry was admitted."""
        if key in self._data:
            self._data[key] = value
            self._data.move_to_end(key)
            return True
        if self.capacity == 0:
            self.stats.rejections += 1
            return False
        if len(self._data) >= self.capacity:
            victim = next(iter(self._data))
            if self._sketch is not None and \
                    self._sketch.estimate(key) < self._sketch.estimate(victim):
                self.stats.rejections += 1
                return False
            self._data.popitem(last=False)
            self.stats.evictions += 1
        self._data[key] = value
        return True

    def invalidate(self, key: Hashable) -> bool:
        """Drop one entry; True when it was present."""
        if key in self._data:
            del self._data[key]
            self.stats.invalidations += 1
            return True
        return False

    def invalidate_where(self, pred: Callable[[Hashable], bool]) -> list:
        """Drop every entry whose key satisfies ``pred``; returns them."""
        doomed = [k for k in self._data if pred(k)]
        for k in doomed:
            self.invalidate(k)
        return doomed

    def clear(self) -> None:
        self.stats.invalidations += len(self._data)
        self._data.clear()
