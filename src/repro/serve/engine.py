"""Low-latency community-sharded inference over a trained GCN.

``CommunityServer`` serves final-layer embeddings for single nodes out of
a trained ``ParallelADMMTrainer`` model (weights + community layout).
The community structure the trainer exploits for locality is exactly
what makes inference cacheable:

  * the node set lives on one packed Σ-bucket-rows plane
    (``CommunityLayout.device_layout(1)``), so community m's rows are a
    contiguous ``row_counts[m]``-row slice at ``local_offsets[m]``;
  * an **embedding cache** holds per-(community, layer) activation
    blocks; a request for node v whose ``(comm(v), L)`` block is resident
    is answered by a single static row gather out of that block — no
    aggregation, no collectives, nothing full-graph-sized in the program
    (the ``serve_hit`` analyze config proves this on the compiled HLO);
  * a **halo cache** holds the cross-community halves
    Σ_{r∈N_m\\{m}} Ã_{m,r} Z_{l-1}[r] of each aggregation, so a miss
    whose inputs are clean recomputes only the *self* block product and
    the layer GEMM; only a cold/invalidated neighbourhood pays for the
    packed-kernel halo pass (``kernels.ops.community_halo_spmm``);
  * a feature update to node v dirties exactly the reader closure of
    v's community (``graph.read_closure``) — v's own community's cache
    lines plus the halo entries of communities that read it
    (``graph.halo_readers``); everything else stays served from cache.

Both caches are fixed-capacity LRU with optional Zipf-aware admission
(``serve.cache``); ``ServeConfig(cache_enabled=False)`` zeroes the
capacities, which makes every request recompute — the benchmark baseline
— while running the *same* compiled programs, so enabled vs disabled
parity is bitwise.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gcn, graph, messages
from repro.kernels import ops as kops
from repro.serve.batcher import RequestBatcher

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (frozen, like TrainerConfig)."""

    embed_capacity: int = 16     # (community, layer) activation blocks
    halo_capacity: int = 64      # (community, layer) halo aggregates
    cache_enabled: bool = True   # False: capacity-0 caches (baseline)
    admission: str = "zipf"      # "zipf" | "lru"
    sketch_sample: int = 1024    # admission sketch aging period
    fused: bool = False          # cold-path agg→GEMM via the fused kernel
    max_batch: int = 1024        # per-community batch bound (ladder cap)

    def __post_init__(self):
        if self.admission not in ("zipf", "lru"):
            raise ValueError(f"unknown admission {self.admission!r}")


# --- jitted programs ------------------------------------------------------
# jax.jit caches one executable per operand-shape signature; the batcher
# pads every varying dim to a pad_ladder bucket, so each helper compiles a
# small static set of programs that serve all batch compositions.

@jax.jit
def _take_rows(block: Array, rows: Array) -> Array:
    """The hit path: gather requested rows out of one community block."""
    return jnp.take(block, rows, axis=0, mode="fill", fill_value=0.0)


@jax.jit
def _scatter_rows(plane: Array, block: Array, start) -> Array:
    return jax.lax.dynamic_update_slice(plane, block, (start, 0))


@functools.partial(jax.jit, static_argnames=("rc",))
def _slice_rows(plane: Array, start, *, rc: int) -> Array:
    return jax.lax.dynamic_slice(plane, (start, 0), (rc, plane.shape[1]))


@functools.partial(jax.jit, static_argnames=("act",))
def _layer_out(agg: Array, w: Array, *, act: str) -> Array:
    return gcn.activation_fn(act)(agg @ w)


@jax.jit
def _self_plus_halo(a_self: Array, z_prev: Array, halo: Array) -> Array:
    return a_self @ z_prev + halo


@functools.partial(jax.jit, static_argnames=("rc",))
def _halo_row(ell_row: Array, off_row: Array, mask_row: Array,
              self_row: Array, plane: Array, rc_arr: Array,
              nc_row: Array, *, rc: int) -> Array:
    out = kops.community_halo_spmm(ell_row, off_row, mask_row, self_row,
                                   plane, rc_arr, nc_row)
    return out[0, :rc]


@functools.partial(jax.jit, static_argnames=("rc", "act"))
def _fused_row(ell_row: Array, off_row: Array, mask_row: Array,
               plane: Array, w: Array, rc_arr: Array, nc_row: Array,
               *, rc: int, act: str) -> Array:
    out = kops.community_spmm_ell_fused(ell_row, off_row, mask_row,
                                        plane, w, rc_arr, nc_row)
    return gcn.activation_fn(act)(out[0, :rc])


class CommunityServer:
    """Cached community-block inference over a trained model."""

    def __init__(self, cfg: gcn.GCNConfig, layout: graph.CommunityLayout,
                 weights: Sequence[Array], features: np.ndarray,
                 config: ServeConfig | None = None):
        from repro.serve.cache import LRUCache

        self.cfg = cfg
        self.layout = layout
        self.config = config or ServeConfig()
        self.weights = [jnp.asarray(w, jnp.float32) for w in weights]
        if len(self.weights) != cfg.num_layers:
            raise ValueError(f"{len(self.weights)} weight matrices for a "
                             f"{cfg.num_layers}-layer model")

        m = layout.num_parts
        csr = layout.compress()
        self.dl = dl = layout.device_layout(1)   # one resident plane
        rows, nbr = csr.ell_row_counts()
        self.row_counts = np.asarray(rows, np.int32)              # (M,)
        offsets = messages.plane_read_offsets(
            csr.ell_indices, csr.ell_mask, dl.local_offsets)
        self_mask = messages.self_slot_mask(csr.ell_indices, csr.ell_mask)
        # per-community static kernel operands, split once so the hot loop
        # never pays a device-slice dispatch: every row shares the shape
        # (1, max_deg, ...) so all communities hit the same programs
        blocks = np.asarray(csr.ell_blocks, np.float32)
        self._ell_row = [jnp.asarray(blocks[i:i + 1]) for i in range(m)]
        self._off_row = [jnp.asarray(offsets[i:i + 1]) for i in range(m)]
        self._mask_row = [jnp.asarray(np.asarray(csr.ell_mask)[i:i + 1])
                          for i in range(m)]
        self._self_row = [jnp.asarray(self_mask[i:i + 1]) for i in range(m)]
        self._nc_row = [jnp.asarray(np.asarray(nbr)[i:i + 1]) for i in range(m)]
        self._rc_arr = [jnp.asarray(self.row_counts[i:i + 1]) for i in range(m)]
        ab = np.asarray(layout.a_blocks, np.float32)
        self._a_self = [jnp.asarray(
            ab[i, i, :self.row_counts[i], :self.row_counts[i]])
            for i in range(m)]

        # dependency tables (incremental invalidation)
        self.neighbor_mask = np.asarray(layout.neighbor_mask, bool)
        self.readers = graph.halo_readers(self.neighbor_mask)
        self.neighbors = [np.flatnonzero(self.neighbor_mask[i]).astype(
            np.int32) for i in range(m)]

        # node id -> (community, block-local row, plane row)
        perm = np.asarray(layout.perm)
        n_nodes = int((perm >= 0).sum())
        node_comm = np.zeros(n_nodes, np.int32)
        node_row = np.zeros(n_nodes, np.int32)
        for slot, node in enumerate(perm):
            if node >= 0:
                node_comm[node] = slot // layout.n_pad
                node_row[node] = slot % layout.n_pad
        self.node_comm, self.node_row = node_comm, node_row
        self._node_plane_row = (
            np.asarray(dl.local_offsets)[node_comm] + node_row).astype(
            np.int32)
        self.batcher = RequestBatcher(node_comm, node_row,
                                      max_batch=self.config.max_batch)

        # layer-0 plane: packed features — resident, always fresh
        z0 = dl.pack_state(layout.pack(
            np.asarray(features, np.float32)))
        self.z0_plane = jnp.asarray(z0)

        c = self.config
        ecap = c.embed_capacity if c.cache_enabled else 0
        hcap = c.halo_capacity if c.cache_enabled else 0
        self.embed_cache = LRUCache(ecap, admission=c.admission,
                                    sample=c.sketch_sample)
        self.halo_cache = LRUCache(hcap, admission=c.admission,
                                   sample=c.sketch_sample)
        self.request_hits = 0
        self.request_total = 0
        self.block_computes = 0
        self.halo_computes = 0

    @classmethod
    def from_trainer(cls, trainer, config: ServeConfig | None = None
                     ) -> "CommunityServer":
        """Build over a trained ``ParallelADMMTrainer``'s weights/layout."""
        return cls(trainer.cfg, trainer.layout,
                   trainer.state.weights, trainer.graph.features,
                   config=config)

    # --- block computation ------------------------------------------------

    def _block0(self, m: int) -> Array:
        rc = int(self.row_counts[m])
        return _slice_rows(self.z0_plane, int(self.dl.local_offsets[m]),
                           rc=rc)

    def _block(self, m: int, layer: int) -> Array:
        """(row_counts[m], C_layer) activation block, cached."""
        if layer == 0:
            return self._block0(m)
        key = (m, layer)
        val = self.embed_cache.get(key)
        if val is not None:
            return val
        val = self._compute_block(m, layer)
        self.embed_cache.put(key, val)
        return val

    def _neighbor_plane(self, m: int, layer: int, with_self: bool) -> Array:
        """Scatter the (clean) layer blocks community m reads onto a
        scratch plane for the packed kernel.  Recursion bottoms out at
        the always-fresh layer-0 feature plane."""
        if layer == 0 and with_self:
            return self.z0_plane
        c = self.cfg.layer_dims[layer]
        plane = jnp.zeros((self.dl.plane_rows, c), jnp.float32)
        for r in self.neighbors[m]:
            if not with_self and int(r) == m:
                continue
            blk = self._block(int(r), layer)
            plane = _scatter_rows(plane, blk,
                                  int(self.dl.local_offsets[int(r)]))
        return plane

    def _compute_halo(self, m: int, layer: int) -> Array:
        """Σ_{r∈N_m\\{m}} Ã_{m,r} Z_{layer-1}[r] via the packed kernel."""
        self.halo_computes += 1
        plane = self._neighbor_plane(m, layer - 1, with_self=False)
        return _halo_row(self._ell_row[m], self._off_row[m],
                         self._mask_row[m], self._self_row[m], plane,
                         self._rc_arr[m], self._nc_row[m],
                         rc=int(self.row_counts[m]))

    def _compute_block(self, m: int, layer: int) -> Array:
        self.block_computes += 1
        act = self.cfg.activation if layer < self.cfg.num_layers \
            else "identity"
        key = (m, layer)
        halo = self.halo_cache.get(key)
        if halo is None and self.config.fused:
            # cold path through the fused aggregation→GEMM kernel: one
            # pass, no halo intermediate — and therefore no halo entry to
            # admit (the fused trade: faster cold recompute, fuller
            # recompute after the next invalidation)
            plane = self._neighbor_plane(m, layer - 1, with_self=True)
            return _fused_row(self._ell_row[m], self._off_row[m],
                              self._mask_row[m], plane,
                              self.weights[layer - 1], self._rc_arr[m],
                              self._nc_row[m],
                              rc=int(self.row_counts[m]), act=act)
        if halo is None:
            halo = self._compute_halo(m, layer)
            self.halo_cache.put(key, halo)
        z_prev = self._block(m, layer - 1)
        agg = _self_plus_halo(self._a_self[m], z_prev, halo)
        return _layer_out(agg, self.weights[layer - 1], act=act)

    # --- serving ----------------------------------------------------------

    def serve(self, node_ids: np.ndarray) -> np.ndarray:
        """Final-layer embeddings for ``node_ids``, in request order."""
        ids = np.asarray(node_ids)
        n_l = self.cfg.num_layers
        out = np.zeros((len(ids), self.cfg.layer_dims[-1]), np.float32)
        for b in self.batcher.coalesce(ids):
            hit = (b.comm, n_l) in self.embed_cache
            block = self._block(b.comm, n_l)
            self.request_total += b.count
            if hit:
                self.request_hits += b.count
            vals = _take_rows(block, jnp.asarray(b.rows))
            out[b.positions] = np.asarray(vals)[:b.count]
        return out

    # --- incremental invalidation ----------------------------------------

    def update_features(self, node_ids: np.ndarray, feats: np.ndarray
                        ) -> dict:
        """Apply a feature update and invalidate exactly its read closure.

        Returns the dropped cache keys and the per-hop dirty community
        sets — the tests assert these match the dependency tables'
        prediction, and that everything *not* listed keeps serving from
        cache."""
        ids = np.asarray(node_ids, np.int64)
        feats = np.asarray(feats, np.float32)
        if feats.shape != (len(ids), self.cfg.layer_dims[0]):
            raise ValueError(f"feats shape {feats.shape} != "
                             f"({len(ids)}, {self.cfg.layer_dims[0]})")
        rows = self._node_plane_row[ids]
        self.z0_plane = self.z0_plane.at[jnp.asarray(rows)].set(
            jnp.asarray(feats))

        n_l = self.cfg.num_layers
        seeds = np.unique(self.node_comm[ids])
        closure = graph.read_closure(self.neighbor_mask, seeds, hops=n_l)
        nbr_cross = self.neighbor_mask & ~np.eye(
            self.neighbor_mask.shape[0], dtype=bool)
        dropped_embed, dropped_halo = [], []
        for layer in range(1, n_l + 1):
            for m in closure[layer]:
                if self.embed_cache.invalidate((int(m), layer)):
                    dropped_embed.append((int(m), layer))
            # halo(m, layer) reads Z_{layer-1} of N_m \ {m}
            halo_dirty = np.flatnonzero(
                nbr_cross[:, closure[layer - 1]].any(axis=1))
            for m in halo_dirty:
                if self.halo_cache.invalidate((int(m), layer)):
                    dropped_halo.append((int(m), layer))
        return {"dirty": [c.tolist() for c in closure],
                "embed": dropped_embed, "halo": dropped_halo}

    # --- introspection ----------------------------------------------------

    def stats(self) -> dict:
        return {
            "requests": {
                "total": self.request_total,
                "hits": self.request_hits,
                "hit_rate": round(
                    self.request_hits / max(self.request_total, 1), 4),
            },
            "block_computes": self.block_computes,
            "halo_computes": self.halo_computes,
            "embed_cache": self.embed_cache.stats.as_dict(),
            "halo_cache": self.halo_cache.stats.as_dict(),
        }

    def reset_stats(self) -> None:
        self.request_hits = self.request_total = 0
        self.block_computes = self.halo_computes = 0
        self.embed_cache.stats.reset()
        self.halo_cache.stats.reset()

    def hit_path_lowered(self, bucket: int = 64):
        """The steady-state hit program, lowered for analysis: one
        community block in, the requested rows out.  The analyze config
        proves the compiled text has zero collectives and nothing
        full-plane-sized (serve.analyze expectations)."""
        rc = int(self.row_counts.max())
        block = jax.ShapeDtypeStruct((rc, self.cfg.layer_dims[-1]),
                                     jnp.float32)
        rows = jax.ShapeDtypeStruct((int(bucket),), jnp.int32)
        return _take_rows.lower(block, rows)

    def halo_path_lowered(self, layer: int = 1):
        """The miss-path halo kernel program, lowered for analysis (the
        plane operand is legitimately Σ-bucket-rows tall here; the rule
        checked is zero collectives, single-device recompute)."""
        m = 0
        c = self.cfg.layer_dims[layer - 1]
        sd = jax.ShapeDtypeStruct
        return _halo_row.lower(
            sd(self._ell_row[m].shape, jnp.float32),
            sd(self._off_row[m].shape, jnp.int32),
            sd(self._mask_row[m].shape, jnp.float32),
            sd(self._self_row[m].shape, jnp.float32),
            sd((self.dl.plane_rows, c), jnp.float32),
            sd((1,), jnp.int32),
            sd(self._nc_row[m].shape, jnp.int32),
            rc=int(self.row_counts[m]))
