"""repro.serve — low-latency community-sharded GCN inference.

The serving counterpart of the training stack (docs/serving.md): a
trained model's community layout makes single-node inference cacheable —
``CommunityServer`` answers hits with one static row gather out of a
per-community embedding block, recomputes misses with the packed ELL
kernels over exactly the stale community's rows, and invalidates feature
updates along the community read closure.  ``RequestBatcher`` coalesces
a node-request queue into pad_ladder-bucketed per-community batches;
``zipf_node_stream`` generates the heavy-tailed benchmark traffic.
"""
from repro.serve.batcher import CommunityBatch, RequestBatcher
from repro.serve.cache import CacheStats, FrequencySketch, LRUCache
from repro.serve.engine import CommunityServer, ServeConfig
from repro.serve.traffic import zipf_node_stream

__all__ = [
    "CacheStats", "CommunityBatch", "CommunityServer", "FrequencySketch",
    "LRUCache", "RequestBatcher", "ServeConfig", "zipf_node_stream",
]
