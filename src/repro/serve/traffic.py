"""Synthetic request traffic for the serving benchmark.

The "millions of users" traffic shape is heavy-tailed: a few hot nodes
absorb most lookups.  ``zipf_node_stream`` draws node ids with
probability proportional to ``rank^-s`` over a seeded permutation of the
node set — the permutation spreads the hot ranks across communities in
proportion to community size, so on the size-skewed benchmark graphs the
big communities carry most of the request mass (the regime the
embedding cache exploits).
"""
from __future__ import annotations

import numpy as np


def zipf_node_stream(num_nodes: int, num_requests: int, s: float = 1.1,
                     seed: int = 0) -> np.ndarray:
    """(num_requests,) int32 node ids, Zipf(s)-distributed."""
    if num_nodes <= 0:
        raise ValueError(f"num_nodes must be positive, got {num_nodes}")
    rng = np.random.default_rng(seed)
    weights = (np.arange(1, num_nodes + 1, dtype=np.float64)) ** (-float(s))
    probs = weights / weights.sum()
    nodes = rng.permutation(num_nodes)
    draws = rng.choice(num_nodes, size=int(num_requests), p=probs)
    return nodes[draws].astype(np.int32)
