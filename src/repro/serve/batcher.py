"""Cross-community request batching.

A serving queue arrives as flat node ids in request order; the ELL/gather
programs want per-community row batches.  ``RequestBatcher.coalesce``
groups the queue by community (stable order, so a request's position in
its batch is deterministic) and pads each community's row-index array to
a ``graph.pad_ladder`` bucket — the same geometric {8, 16, 24, 32, 48,
...} ladder the ragged layout pads rows with — so the per-batch shapes
come from a small static set and one compiled gather program per
(bucket, feature-dim) serves every batch composition jit ever sees.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import pad_ladder


@dataclasses.dataclass(frozen=True)
class CommunityBatch:
    """One community's slice of a request batch."""

    comm: int                # community id
    rows: np.ndarray         # (bucket,) int32 rows within the community
    #                          block, padded with 0 past ``count``
    count: int               # true requests in this batch
    positions: np.ndarray    # (count,) indices into the request vector

    @property
    def bucket(self) -> int:
        return int(self.rows.shape[0])


class RequestBatcher:
    """Coalesce node requests into padded per-community row batches."""

    def __init__(self, node_comm: np.ndarray, node_row: np.ndarray,
                 max_batch: int = 1024):
        """``node_comm``/``node_row``: (N,) community id and block-local
        row of every node (from ``CommunityLayout.perm``).  ``max_batch``
        bounds the per-community batch the ladder must cover."""
        self.node_comm = np.asarray(node_comm, dtype=np.int32)
        self.node_row = np.asarray(node_row, dtype=np.int32)
        self.max_batch = int(max_batch)
        self.ladder = pad_ladder(self.max_batch)

    def bucket(self, count: int) -> int:
        """Smallest ladder bucket >= ``count``."""
        if count > self.ladder[-1]:
            raise ValueError(f"batch of {count} exceeds the ladder cap "
                             f"{self.ladder[-1]} (max_batch={self.max_batch})")
        return next(v for v in self.ladder if v >= count)

    def coalesce(self, node_ids: np.ndarray) -> list[CommunityBatch]:
        """Group a request vector by community.

        Returns batches sorted by community id; each request keeps its
        queue position so the caller can scatter results back in request
        order.
        """
        ids = np.asarray(node_ids, dtype=np.int64)
        if ids.ndim != 1:
            raise ValueError(f"node_ids must be 1-D, got shape {ids.shape}")
        comms = self.node_comm[ids]
        order = np.argsort(comms, kind="stable")
        batches: list[CommunityBatch] = []
        for comm in np.unique(comms):
            pos = order[comms[order] == comm]
            rows = self.node_row[ids[pos]]
            b = self.bucket(len(pos))
            padded = np.zeros(b, dtype=np.int32)
            padded[:len(pos)] = rows
            batches.append(CommunityBatch(
                comm=int(comm), rows=padded, count=int(len(pos)),
                positions=pos.astype(np.int64)))
        return batches
