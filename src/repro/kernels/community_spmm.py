"""Pallas TPU kernels: community-blocked sparse-dense matmul (Ã · Z).

The GCN ADMM hot spot is the aggregation ``Σ_r Ã_{m,r} Z_r``.  On TPU we do
NOT port a CSR gather-SpMM (no efficient per-element gather on the VPU);
instead the paper's community structure gives a *block*-sparse layout:
dense (n_pad × n_pad) community blocks with a (M × M) block mask — each
present block is a dense MXU matmul on 128-aligned VMEM tiles and absent
blocks are skipped with ``@pl.when`` (DESIGN.md §2, hardware adaptation).

Two kernels over the same math:

  * ``community_spmm`` — dense (M, n_pad, n_pad) block rows + neighbour
    mask; grid (row-tiles, col-tiles, M), the community (reduction) axis
    innermost so the output tile stays resident in VMEM.
  * ``community_spmm_ell`` — block-compressed (ELL) rows: only the max_deg
    stored neighbour blocks are iterated, and the gathered Z block for
    slot d is chosen *at DMA time* from the scalar-prefetched
    ``ell_indices`` (PrefetchScalarGridSpec), so the reduction is O(max_deg)
    instead of O(M) and absent/padding slots never touch the MXU.

  a_row:  (M, n_pad, n_pad)   this shard's row of Ã blocks
  z_all:  (M, n_pad, C)       gathered community features
  mask:   (M,)                neighbour mask (True = nonzero block)
  out:    (n_pad, C)

Both kernels derive their grid, block shapes and index maps from a
declarative ``KernelSpec`` (``spmm_spec`` / ``ell_spec``) which
``repro.analysis.rules.pallas`` abstract-interprets to bound every block
DMA against the operand shapes and to estimate the VMEM footprint — the
kernel and the linter read the *same* spec, so they cannot drift.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 256     # rows per tile (8-aligned; 256 divides n_pad)
DEFAULT_TILE_C = 256     # feature cols per tile (128-aligned)


# ---------------------------------------------------------------------------
# Declarative kernel specs (shared by pallas_call and the static linter)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockOperand:
    """One pallas operand: array shape, block shape, and the index map.

    ``index_map`` has the exact pallas signature — grid ids first, then
    any scalar-prefetch operands — and works equally on traced refs (in
    the kernel) and numpy arrays (in the linter).  ``gather_scalar``
    names the scalar-prefetch array whose *values* select this operand's
    leading block (data-dependent DMA): the linter bounds that array's
    value range against the leading block count.
    """
    name: str
    array_shape: tuple[int, ...]
    block_shape: tuple[Optional[int], ...]
    index_map: Callable[..., tuple]
    dtype_bytes: int = 4
    gather_scalar: Optional[str] = None

    def block_bytes(self) -> int:
        n = 1
        for b in self.block_shape:
            if b is not None:
                n *= b
        return n * self.dtype_bytes

    def block_counts(self) -> tuple[int, ...]:
        """Valid block-index range per dim (None dims index elements)."""
        return tuple(dim if b is None else -(-dim // b)
                     for dim, b in zip(self.array_shape, self.block_shape))


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Grid + operands (inputs then output) + scratch, linter-checkable."""
    name: str
    grid: tuple[int, ...]
    operands: tuple[BlockOperand, ...]
    scratch_bytes: int = 0
    scalar_prefetch: tuple[str, ...] = ()

    def vmem_bytes(self) -> int:
        """Footprint estimate: double-buffered operand/output blocks
        (pallas pipelines the DMAs) plus accumulator scratch."""
        return (2 * sum(op.block_bytes() for op in self.operands)
                + self.scratch_bytes)


def _shrink(total: int, tile: int) -> int:
    tile = min(tile, total)
    while total % tile:
        tile //= 2
    return max(tile, 1)


def spmm_spec(m: int, n_pad: int, c: int, *,
              tile_n: int = DEFAULT_TILE_N, tile_c: int = DEFAULT_TILE_C,
              a_bytes: int = 4, z_bytes: int = 4) -> KernelSpec:
    """Spec for the dense-block kernel (grid: row-tiles, col-tiles, M)."""
    tile_n = _shrink(n_pad, tile_n)
    tile_c = _shrink(c, tile_c)
    return KernelSpec(
        name="community_spmm",
        grid=(n_pad // tile_n, c // tile_c, m),
        operands=(
            BlockOperand("mask", (m,), (m,),
                         lambda i, j, r: (0,), 4),
            BlockOperand("a_row", (m, n_pad, n_pad),
                         (None, tile_n, n_pad),
                         lambda i, j, r: (r, i, 0), a_bytes),
            BlockOperand("z_all", (m, n_pad, c),
                         (None, n_pad, tile_c),
                         lambda i, j, r: (r, 0, j), z_bytes),
            BlockOperand("out", (n_pad, c), (tile_n, tile_c),
                         lambda i, j, r: (i, j), z_bytes),
        ),
        scratch_bytes=tile_n * tile_c * 4)


def ell_spec(k: int, max_deg: int, n_pad: int, c: int, m_total: int, *,
             tile_n: int = DEFAULT_TILE_N, tile_c: int = DEFAULT_TILE_C,
             tile_p: Optional[int] = None,
             block_bytes: int = 4, z_bytes: int = 4) -> KernelSpec:
    """Spec for the ELL kernel (grid: k, row-tiles, col-tiles, max_deg,
    contraction-tiles; scalar-prefetched ``ell_indices`` steer the Z DMA)."""
    tile_n = _shrink(n_pad, tile_n)
    tile_c = _shrink(c, tile_c)
    tile_p = _shrink(n_pad, tile_n if tile_p is None else tile_p)
    return KernelSpec(
        name="community_spmm_ell",
        grid=(k, n_pad // tile_n, c // tile_c, max_deg, n_pad // tile_p),
        operands=(
            BlockOperand("ell_blocks", (k, max_deg, n_pad, n_pad),
                         (None, None, tile_n, tile_p),
                         lambda m, i, j, d, p, idx, msk, rows, nbr:
                         (m, d, i, p), block_bytes),
            BlockOperand("z_all", (m_total, n_pad, c),
                         (None, tile_p, tile_c),
                         lambda m, i, j, d, p, idx, msk, rows, nbr:
                         (idx[m, d], p, j), z_bytes,
                         gather_scalar="ell_indices"),
            BlockOperand("out", (k, n_pad, c), (None, tile_n, tile_c),
                         lambda m, i, j, d, p, idx, msk, rows, nbr:
                         (m, i, j), z_bytes),
        ),
        scratch_bytes=tile_n * tile_c * 4,
        scalar_prefetch=("ell_indices", "ell_mask",
                         "row_counts", "nbr_counts"))


def ell_fused_spec(k: int, max_deg: int, n_pad: int, c_in: int, c_out: int,
                   plane_rows: int, *,
                   tile_n: int = DEFAULT_TILE_N,
                   block_bytes: int = 4, z_bytes: int = 4) -> KernelSpec:
    """Spec for the fused aggregation→GEMM kernel.

    Same packed-plane machinery as ``ell_packed_spec`` — the Z DMA reads
    the (plane_rows, C_in) receive plane at the scalar-prefetched 8-row
    offsets — but the grid carries no feature-tile axis: the whole
    (tile_n, C_in) aggregated block accumulates in VMEM scratch across
    the (d, p) reduction steps, and at the last step the per-community
    Z-update GEMM against the VMEM-resident ``w`` block writes the
    (tile_n, C_out) output directly.  The aggregated stack exists only
    as that scratch tile — it never round-trips HBM (GCN feature dims
    are small, so the un-tiled C axes stay well inside the VMEM budget;
    ``repro.analysis.rules.pallas.check_kernel_vmem`` proves it against
    this spec).
    """
    tile_n = _shrink(n_pad, tile_n)
    tile_p = 8
    zb = plane_rows // tile_p
    return KernelSpec(
        name="community_spmm_ell_fused",
        grid=(k, n_pad // tile_n, max_deg, n_pad // tile_p),
        operands=(
            BlockOperand("ell_blocks", (k, max_deg, n_pad, n_pad),
                         (None, None, tile_n, tile_p),
                         lambda m, i, d, p, off8, msk, rows, nbr:
                         (m, d, i, p), block_bytes),
            BlockOperand("z_plane", (plane_rows, c_in),
                         (tile_p, c_in),
                         lambda m, i, d, p, off8, msk, rows, nbr:
                         (jnp.minimum(off8[m, d] + p, zb - 1), 0), z_bytes,
                         gather_scalar="ell_offsets8"),
            BlockOperand("w", (c_in, c_out), (c_in, c_out),
                         lambda m, i, d, p, off8, msk, rows, nbr:
                         (0, 0), z_bytes),
            BlockOperand("out", (k, n_pad, c_out), (None, tile_n, c_out),
                         lambda m, i, d, p, off8, msk, rows, nbr:
                         (m, i, 0), z_bytes),
        ),
        scratch_bytes=tile_n * c_in * 4,
        scalar_prefetch=("ell_offsets8", "ell_mask",
                         "row_counts", "nbr_counts"))


def ell_packed_spec(k: int, max_deg: int, n_pad: int, c: int,
                    plane_rows: int, *,
                    tile_n: int = DEFAULT_TILE_N, tile_c: int = DEFAULT_TILE_C,
                    block_bytes: int = 4, z_bytes: int = 4) -> KernelSpec:
    """Spec for the packed-plane ELL kernel.

    Z is the packed Σ-bucket-rows receive plane ``(plane_rows, C)`` —
    no ``(M, n_pad, C)`` stride.  The scalar-prefetched ``ell_offsets8``
    plane carries each stored neighbour's starting row *in 8-row units*
    (every bucket size and plane offset is a multiple of the (8, 128)
    tile quantum), so the contraction tiles at ``tile_p = 8`` and the Z
    DMA for contraction step p starts at block ``off8[m, d] + p``.  The
    ``jnp.minimum`` clamp keeps the map in bounds at grid corners past a
    neighbour's true rows — those tiles are dead (the ``nbr_counts``
    guard skips them) but pallas still evaluates their index map.
    """
    tile_n = _shrink(n_pad, tile_n)
    tile_c = _shrink(c, tile_c)
    tile_p = 8
    zb = plane_rows // tile_p
    return KernelSpec(
        name="community_spmm_ell_packed",
        grid=(k, n_pad // tile_n, c // tile_c, max_deg, n_pad // tile_p),
        operands=(
            BlockOperand("ell_blocks", (k, max_deg, n_pad, n_pad),
                         (None, None, tile_n, tile_p),
                         lambda m, i, j, d, p, off8, msk, rows, nbr:
                         (m, d, i, p), block_bytes),
            BlockOperand("z_plane", (plane_rows, c),
                         (tile_p, tile_c),
                         lambda m, i, j, d, p, off8, msk, rows, nbr:
                         (jnp.minimum(off8[m, d] + p, zb - 1), j), z_bytes,
                         gather_scalar="ell_offsets8"),
            BlockOperand("out", (k, n_pad, c), (None, tile_n, tile_c),
                         lambda m, i, j, d, p, off8, msk, rows, nbr:
                         (m, i, j), z_bytes),
        ),
        scratch_bytes=tile_n * tile_c * 4,
        scalar_prefetch=("ell_offsets8", "ell_mask",
                         "row_counts", "nbr_counts"))


# ---------------------------------------------------------------------------
# Dense-block kernel
# ---------------------------------------------------------------------------


def _spmm_kernel(mask_ref, a_ref, z_ref, o_ref, acc_scr):
    r = pl.program_id(2)
    n_r = pl.num_programs(2)

    @pl.when(r == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(mask_ref[r] != 0)
    def _accum():
        a = a_ref[...]                       # (tile_n, n_pad)
        z = z_ref[...]                       # (n_pad, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when(r == n_r - 1)
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "interpret"))
def community_spmm(a_row: jax.Array, z_all: jax.Array, mask: jax.Array,
                   *, tile_n: int = DEFAULT_TILE_N,
                   tile_c: int = DEFAULT_TILE_C,
                   interpret: bool = False) -> jax.Array:
    m, n_pad, _ = a_row.shape
    c = z_all.shape[-1]
    spec = spmm_spec(m, n_pad, c, tile_n=tile_n, tile_c=tile_c,
                     a_bytes=a_row.dtype.itemsize,
                     z_bytes=z_all.dtype.itemsize)
    mask_op, a_op, z_op, out_op = spec.operands
    return pl.pallas_call(
        _spmm_kernel,
        grid=spec.grid,
        in_specs=[
            pl.BlockSpec(mask_op.block_shape, mask_op.index_map),
            pl.BlockSpec(a_op.block_shape, a_op.index_map),
            pl.BlockSpec(z_op.block_shape, z_op.index_map),
        ],
        out_specs=pl.BlockSpec(out_op.block_shape, out_op.index_map),
        out_shape=jax.ShapeDtypeStruct(out_op.array_shape, z_all.dtype),
        scratch_shapes=[_vmem_scratch(
            (out_op.block_shape[0], out_op.block_shape[1]))],
        interpret=interpret,
    )(mask.astype(jnp.int32), a_row, z_all)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Block-compressed (ELL) variant: only the nnz blocks are materialized.
#
# The lane's neighbour blocks arrive pre-gathered in ELL form — row m holds
# its max_deg neighbour blocks plus padding — so the reduction axis is
# max_deg (~constant on power-law community graphs) instead of M.  The
# gathered feature block to multiply against is *data-dependent*
# (z_all[ell_indices[m, d]]): ``ell_indices`` is scalar-prefetched so the
# BlockSpec index_map can steer the Z DMA before the body runs, and padding
# slots (ell_mask == 0) skip the MXU work with ``@pl.when`` — the same
# predication trick as the dense kernel's absent-block skip.
#
# Ragged (size-aware) padding: two more scalar-prefetched planes,
# ``row_counts`` (k,) and ``nbr_counts`` (k, max_deg), carry each lane's
# true padded row count and each stored neighbour's.  The contraction axis
# is tiled (grid axis 4, ``tile_p``), and a tile is accumulated only when
# (a) the block is real, (b) the output row tile starts below the lane's
# row count and (c) the contraction tile starts below the neighbour's row
# count — pad rows drop out of the DMA+accumulate at tile granularity, so
# work tracks the bucketed community sizes instead of the global n_pad.
# With counts pinned at n_pad (the default) every guard is trivially live
# and the kernel is the historic global-pad program.
# ---------------------------------------------------------------------------


def _spmm_ell_kernel(idx_ref, msk_ref, rows_ref, nbr_ref, a_ref, z_ref,
                     o_ref, acc_scr, *, tile_n: int, tile_p: int):
    m = pl.program_id(0)
    i = pl.program_id(1)
    d = pl.program_id(3)
    p = pl.program_id(4)
    n_d = pl.num_programs(3)
    n_p = pl.num_programs(4)

    @pl.when((d == 0) & (p == 0))
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = ((msk_ref[m, d] != 0)
            & (i * tile_n < rows_ref[m])         # output rows are real
            & (p * tile_p < nbr_ref[m, d]))      # neighbour rows are real

    @pl.when(live)
    def _accum():
        a = a_ref[...].astype(jnp.float32)       # (tile_n, tile_p)
        z = z_ref[...].astype(jnp.float32)       # (tile_p, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when((d == n_d - 1) & (p == n_p - 1))
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "tile_p",
                                             "interpret"))
def community_spmm_ell(ell_blocks: jax.Array, ell_indices: jax.Array,
                       ell_mask: jax.Array, z_all: jax.Array,
                       row_counts: jax.Array | None = None,
                       nbr_counts: jax.Array | None = None,
                       *, tile_n: int = DEFAULT_TILE_N,
                       tile_c: int = DEFAULT_TILE_C,
                       tile_p: int | None = None,
                       interpret: bool = False) -> jax.Array:
    """Σ_d mask[m,d] · blocks[m,d] @ z_all[idx[m,d]] — O(nnz·n_pad²·C),
    and with ragged row counts O(Σ bucket_m · bucket_d · C) only.

    ell_blocks:  (k, max_deg, n_pad, n_pad) — a shard's ELL rows (f32 or
                 bf16; accumulation always f32)
    ell_indices: (k, max_deg) int32 global community ids into z_all
    ell_mask:    (k, max_deg) — nonzero = real block, 0 = padding slot
    z_all:       (M, n_pad, C) gathered community features
    row_counts:  optional (k,) int32 — lane m's padded rows; output row
                 tiles past it are skipped (written as zero)
    nbr_counts:  optional (k, max_deg) int32 — rows of each stored
                 neighbour; contraction tiles past it are skipped
    returns      (k, n_pad, C)
    """
    from jax.experimental.pallas import tpu as pltpu

    k, max_deg, n_pad, _ = ell_blocks.shape
    m_total, _, c = z_all.shape
    spec = ell_spec(k, max_deg, n_pad, c, m_total,
                    tile_n=tile_n, tile_c=tile_c, tile_p=tile_p,
                    block_bytes=ell_blocks.dtype.itemsize,
                    z_bytes=z_all.dtype.itemsize)
    a_op, z_op, out_op = spec.operands
    eff_tile_n = out_op.block_shape[1]
    eff_tile_p = z_op.block_shape[1]

    if row_counts is None:
        row_counts = jnp.full((k,), n_pad, jnp.int32)
    if nbr_counts is None:
        nbr_counts = jnp.full((k, max_deg), n_pad, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,     # ell_indices, ell_mask, rows, nbrs (SMEM)
        grid=spec.grid,
        in_specs=[
            pl.BlockSpec(a_op.block_shape, a_op.index_map),
            pl.BlockSpec(z_op.block_shape, z_op.index_map),
        ],
        out_specs=pl.BlockSpec(out_op.block_shape, out_op.index_map),
        scratch_shapes=[_vmem_scratch(
            (out_op.block_shape[1], out_op.block_shape[2]))],
    )
    return pl.pallas_call(
        functools.partial(_spmm_ell_kernel, tile_n=eff_tile_n,
                          tile_p=eff_tile_p),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_op.array_shape, z_all.dtype),
        interpret=interpret,
    )(ell_indices.astype(jnp.int32), ell_mask.astype(jnp.int32),
      row_counts.astype(jnp.int32), nbr_counts.astype(jnp.int32),
      ell_blocks, z_all)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "interpret"))
def community_spmm_ell_packed(ell_blocks: jax.Array, ell_offsets: jax.Array,
                              ell_mask: jax.Array, z_plane: jax.Array,
                              row_counts: jax.Array,
                              nbr_counts: jax.Array,
                              *, tile_n: int = DEFAULT_TILE_N,
                              tile_c: int = DEFAULT_TILE_C,
                              interpret: bool = False) -> jax.Array:
    """ELL aggregation over the *packed* feature plane.

    Same math as ``community_spmm_ell`` but Z arrives as the packed
    Σ-bucket-rows receive plane instead of the (M, n_pad, C) stride —
    neighbour d of lane m occupies rows [offsets[m, d],
    offsets[m, d] + nbr_counts[m, d]).

    ell_blocks:  (k, max_deg, n_pad, n_pad) — f32 or bf16 ELL rows
    ell_offsets: (k, max_deg) int32 packed row offsets, 8-aligned;
                 masked-out slots may carry any in-plane value (0 is
                 conventional — their tiles are skipped)
    ell_mask:    (k, max_deg) — nonzero = real block
    z_plane:     (plane_rows, C), plane_rows a multiple of 8
    row_counts:  (k,) int32 — lane's true padded rows (8-aligned)
    nbr_counts:  (k, max_deg) int32 — each stored neighbour's rows
    returns      (k, n_pad, C) blocked output, rows past row_counts zero
    """
    from jax.experimental.pallas import tpu as pltpu

    k, max_deg, n_pad, _ = ell_blocks.shape
    plane_rows, c = z_plane.shape
    spec = ell_packed_spec(k, max_deg, n_pad, c, plane_rows,
                           tile_n=tile_n, tile_c=tile_c,
                           block_bytes=ell_blocks.dtype.itemsize,
                           z_bytes=z_plane.dtype.itemsize)
    a_op, z_op, out_op = spec.operands
    eff_tile_n = out_op.block_shape[1]

    # 8-row-unit offsets; masked slots pinned at 0 so every prefetched
    # value indexes inside the plane (the linter bounds the value range)
    off8 = jnp.where(ell_mask != 0, ell_offsets // 8, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,   # offsets8, ell_mask, rows, nbrs (SMEM)
        grid=spec.grid,
        in_specs=[
            pl.BlockSpec(a_op.block_shape, a_op.index_map),
            pl.BlockSpec(z_op.block_shape, z_op.index_map),
        ],
        out_specs=pl.BlockSpec(out_op.block_shape, out_op.index_map),
        scratch_shapes=[_vmem_scratch(
            (out_op.block_shape[1], out_op.block_shape[2]))],
    )
    return pl.pallas_call(
        functools.partial(_spmm_ell_kernel, tile_n=eff_tile_n, tile_p=8),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_op.array_shape, z_plane.dtype),
        interpret=interpret,
    )(off8.astype(jnp.int32), ell_mask.astype(jnp.int32),
      row_counts.astype(jnp.int32), nbr_counts.astype(jnp.int32),
      ell_blocks, z_plane)


# ---------------------------------------------------------------------------
# Fused aggregation→Z-update: one pass computes (Σ_d Ã[m,d] Z_d) @ W with
# the aggregated (tile_n, C_in) block held in VMEM scratch the whole time.
#
# The unfused pipeline runs the packed ELL aggregation and the Z-update
# GEMM as two XLA calls, writing the (k, n_pad, C_in) aggregate to HBM
# between them and reading it straight back.  Here the grid drops the
# feature-tile axis (GCN feature dims are narrow), the reduction over
# (d, p) accumulates into the same f32 scratch as the packed kernel — so
# the aggregate is *bitwise* the packed kernel's — and the final grid
# step applies the GEMM against the VMEM-resident W block and writes the
# (tile_n, C_out) result.  The aggregate never exists in HBM; the
# ``memory/fused-no-intermediate`` analysis rule proves the compiled
# trainer step keeps it that way.
# ---------------------------------------------------------------------------


def _spmm_ell_fused_kernel(off_ref, msk_ref, rows_ref, nbr_ref, a_ref,
                           z_ref, w_ref, o_ref, agg_scr, *,
                           tile_n: int, tile_p: int):
    m = pl.program_id(0)
    i = pl.program_id(1)
    d = pl.program_id(2)
    p = pl.program_id(3)
    n_d = pl.num_programs(2)
    n_p = pl.num_programs(3)

    @pl.when((d == 0) & (p == 0))
    def _init():
        agg_scr[...] = jnp.zeros_like(agg_scr)

    live = ((msk_ref[m, d] != 0)
            & (i * tile_n < rows_ref[m])         # output rows are real
            & (p * tile_p < nbr_ref[m, d]))      # neighbour rows are real

    @pl.when(live)
    def _accum():
        a = a_ref[...].astype(jnp.float32)       # (tile_n, tile_p)
        z = z_ref[...].astype(jnp.float32)       # (tile_p, c_in)
        agg_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when((d == n_d - 1) & (p == n_p - 1))
    def _write():
        w = w_ref[...].astype(jnp.float32)       # (c_in, c_out)
        o_ref[...] = jnp.dot(agg_scr[...], w,
                             preferred_element_type=jnp.float32
                             ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def community_spmm_ell_fused(ell_blocks: jax.Array, ell_offsets: jax.Array,
                             ell_mask: jax.Array, z_plane: jax.Array,
                             w: jax.Array,
                             row_counts: jax.Array,
                             nbr_counts: jax.Array,
                             *, tile_n: int = DEFAULT_TILE_N,
                             interpret: bool = False) -> jax.Array:
    """(Σ_d mask[m,d] · blocks[m,d] @ plane[off[m,d]:...]) @ W in one pass.

    Operands are exactly ``community_spmm_ell_packed``'s plus the
    (C_in, C_out) Z-update weight block ``w``.  The aggregation
    accumulates in the same order (and the same f32 scratch) as the
    packed kernel — the intermediate aggregate is bitwise the unfused
    kernel's — and the closing GEMM is one f32 dot per output tile, so
    fused-vs-unfused *outputs* agree to dot-reassociation tolerance
    (~1e-6 at GCN widths), not bitwise: XLA is free to split the unfused
    ``agg @ w`` contraction differently.  Returns (k, n_pad, C_out) with
    rows past ``row_counts`` zero.
    """
    from jax.experimental.pallas import tpu as pltpu

    k, max_deg, n_pad, _ = ell_blocks.shape
    plane_rows, c_in = z_plane.shape
    c_out = w.shape[-1]
    spec = ell_fused_spec(k, max_deg, n_pad, c_in, c_out, plane_rows,
                          tile_n=tile_n,
                          block_bytes=ell_blocks.dtype.itemsize,
                          z_bytes=z_plane.dtype.itemsize)
    a_op, z_op, w_op, out_op = spec.operands
    eff_tile_n = out_op.block_shape[1]

    # 8-row-unit offsets; masked slots pinned at 0 so every prefetched
    # value indexes inside the plane (the linter bounds the value range)
    off8 = jnp.where(ell_mask != 0, ell_offsets // 8, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,   # offsets8, ell_mask, rows, nbrs (SMEM)
        grid=spec.grid,
        in_specs=[
            pl.BlockSpec(a_op.block_shape, a_op.index_map),
            pl.BlockSpec(z_op.block_shape, z_op.index_map),
            pl.BlockSpec(w_op.block_shape, w_op.index_map),
        ],
        out_specs=pl.BlockSpec(out_op.block_shape, out_op.index_map),
        scratch_shapes=[_vmem_scratch((eff_tile_n, c_in))],
    )
    return pl.pallas_call(
        functools.partial(_spmm_ell_fused_kernel, tile_n=eff_tile_n,
                          tile_p=8),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_op.array_shape, z_plane.dtype),
        interpret=interpret,
    )(off8.astype(jnp.int32), ell_mask.astype(jnp.int32),
      row_counts.astype(jnp.int32), nbr_counts.astype(jnp.int32),
      ell_blocks, z_plane, w)
