"""Pallas TPU kernels: community-blocked sparse-dense matmul (Ã · Z).

The GCN ADMM hot spot is the aggregation ``Σ_r Ã_{m,r} Z_r``.  On TPU we do
NOT port a CSR gather-SpMM (no efficient per-element gather on the VPU);
instead the paper's community structure gives a *block*-sparse layout:
dense (n_pad × n_pad) community blocks with a (M × M) block mask — each
present block is a dense MXU matmul on 128-aligned VMEM tiles and absent
blocks are skipped with ``@pl.when`` (DESIGN.md §2, hardware adaptation).

Two kernels over the same math:

  * ``community_spmm`` — dense (M, n_pad, n_pad) block rows + neighbour
    mask; grid (row-tiles, col-tiles, M), the community (reduction) axis
    innermost so the output tile stays resident in VMEM.
  * ``community_spmm_ell`` — block-compressed (ELL) rows: only the max_deg
    stored neighbour blocks are iterated, and the gathered Z block for
    slot d is chosen *at DMA time* from the scalar-prefetched
    ``ell_indices`` (PrefetchScalarGridSpec), so the reduction is O(max_deg)
    instead of O(M) and absent/padding slots never touch the MXU.

  a_row:  (M, n_pad, n_pad)   this shard's row of Ã blocks
  z_all:  (M, n_pad, C)       gathered community features
  mask:   (M,)                neighbour mask (True = nonzero block)
  out:    (n_pad, C)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 256     # rows per tile (8-aligned; 256 divides n_pad)
DEFAULT_TILE_C = 256     # feature cols per tile (128-aligned)


def _spmm_kernel(mask_ref, a_ref, z_ref, o_ref, acc_scr):
    r = pl.program_id(2)
    n_r = pl.num_programs(2)

    @pl.when(r == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(mask_ref[r] != 0)
    def _accum():
        a = a_ref[...]                       # (tile_n, n_pad)
        z = z_ref[...]                       # (n_pad, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when(r == n_r - 1)
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "interpret"))
def community_spmm(a_row: jax.Array, z_all: jax.Array, mask: jax.Array,
                   *, tile_n: int = DEFAULT_TILE_N,
                   tile_c: int = DEFAULT_TILE_C,
                   interpret: bool = False) -> jax.Array:
    m, n_pad, _ = a_row.shape
    c = z_all.shape[-1]
    tile_n = min(tile_n, n_pad)
    tile_c = min(tile_c, c)
    # shrink tiles to divide evenly (n_pad is 8-aligned by construction)
    while n_pad % tile_n:
        tile_n //= 2
    while c % tile_c:
        tile_c //= 2

    grid = (n_pad // tile_n, c // tile_c, m)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i, j, r: (0,)),   # block mask (SMEM)
            pl.BlockSpec((None, tile_n, n_pad), lambda i, j, r: (r, i, 0)),
            pl.BlockSpec((None, n_pad, tile_c), lambda i, j, r: (r, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_c), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c), z_all.dtype),
        scratch_shapes=[_vmem_scratch((tile_n, tile_c))],
        interpret=interpret,
    )(mask.astype(jnp.int32), a_row, z_all)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Block-compressed (ELL) variant: only the nnz blocks are materialized.
#
# The lane's neighbour blocks arrive pre-gathered in ELL form — row m holds
# its max_deg neighbour blocks plus padding — so the reduction axis is
# max_deg (~constant on power-law community graphs) instead of M.  The
# gathered feature block to multiply against is *data-dependent*
# (z_all[ell_indices[m, d]]): ``ell_indices`` is scalar-prefetched so the
# BlockSpec index_map can steer the Z DMA before the body runs, and padding
# slots (ell_mask == 0) skip the MXU work with ``@pl.when`` — the same
# predication trick as the dense kernel's absent-block skip.
# ---------------------------------------------------------------------------


def _spmm_ell_kernel(idx_ref, msk_ref, a_ref, z_ref, o_ref, acc_scr):
    m = pl.program_id(0)
    d = pl.program_id(3)
    n_d = pl.num_programs(3)

    @pl.when(d == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(msk_ref[m, d] != 0)
    def _accum():
        a = a_ref[...]                       # (tile_n, n_pad)
        z = z_ref[...]                       # (n_pad, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when(d == n_d - 1)
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "interpret"))
def community_spmm_ell(ell_blocks: jax.Array, ell_indices: jax.Array,
                       ell_mask: jax.Array, z_all: jax.Array,
                       *, tile_n: int = DEFAULT_TILE_N,
                       tile_c: int = DEFAULT_TILE_C,
                       interpret: bool = False) -> jax.Array:
    """Σ_d mask[m,d] · blocks[m,d] @ z_all[idx[m,d]] — O(nnz·n_pad²·C).

    ell_blocks:  (k, max_deg, n_pad, n_pad) — a shard's ELL rows
    ell_indices: (k, max_deg) int32 global community ids into z_all
    ell_mask:    (k, max_deg) — nonzero = real block, 0 = padding slot
    z_all:       (M, n_pad, C) gathered community features
    returns      (k, n_pad, C)
    """
    from jax.experimental.pallas import tpu as pltpu

    k, max_deg, n_pad, _ = ell_blocks.shape
    c = z_all.shape[-1]
    tile_n = min(tile_n, n_pad)
    tile_c = min(tile_c, c)
    while n_pad % tile_n:
        tile_n //= 2
    while c % tile_c:
        tile_c //= 2

    grid = (k, n_pad // tile_n, c // tile_c, max_deg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # ell_indices, ell_mask (SMEM)
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, tile_n, n_pad),
                         lambda m, i, j, d, idx, msk: (m, d, i, 0)),
            pl.BlockSpec((None, n_pad, tile_c),
                         lambda m, i, j, d, idx, msk: (idx[m, d], 0, j)),
        ],
        out_specs=pl.BlockSpec((None, tile_n, tile_c),
                               lambda m, i, j, d, idx, msk: (m, i, j)),
        scratch_shapes=[pltpu.VMEM((tile_n, tile_c), jnp.float32)],
    )
    return pl.pallas_call(
        _spmm_ell_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, n_pad, c), z_all.dtype),
        interpret=interpret,
    )(ell_indices.astype(jnp.int32), ell_mask.astype(jnp.int32),
      ell_blocks, z_all)
