"""Pallas TPU kernels: community-blocked sparse-dense matmul (Ã · Z).

The GCN ADMM hot spot is the aggregation ``Σ_r Ã_{m,r} Z_r``.  On TPU we do
NOT port a CSR gather-SpMM (no efficient per-element gather on the VPU);
instead the paper's community structure gives a *block*-sparse layout:
dense (n_pad × n_pad) community blocks with a (M × M) block mask — each
present block is a dense MXU matmul on 128-aligned VMEM tiles and absent
blocks are skipped with ``@pl.when`` (DESIGN.md §2, hardware adaptation).

Two kernels over the same math:

  * ``community_spmm`` — dense (M, n_pad, n_pad) block rows + neighbour
    mask; grid (row-tiles, col-tiles, M), the community (reduction) axis
    innermost so the output tile stays resident in VMEM.
  * ``community_spmm_ell`` — block-compressed (ELL) rows: only the max_deg
    stored neighbour blocks are iterated, and the gathered Z block for
    slot d is chosen *at DMA time* from the scalar-prefetched
    ``ell_indices`` (PrefetchScalarGridSpec), so the reduction is O(max_deg)
    instead of O(M) and absent/padding slots never touch the MXU.

  a_row:  (M, n_pad, n_pad)   this shard's row of Ã blocks
  z_all:  (M, n_pad, C)       gathered community features
  mask:   (M,)                neighbour mask (True = nonzero block)
  out:    (n_pad, C)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 256     # rows per tile (8-aligned; 256 divides n_pad)
DEFAULT_TILE_C = 256     # feature cols per tile (128-aligned)


def _spmm_kernel(mask_ref, a_ref, z_ref, o_ref, acc_scr):
    r = pl.program_id(2)
    n_r = pl.num_programs(2)

    @pl.when(r == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(mask_ref[r] != 0)
    def _accum():
        a = a_ref[...]                       # (tile_n, n_pad)
        z = z_ref[...]                       # (n_pad, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when(r == n_r - 1)
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "interpret"))
def community_spmm(a_row: jax.Array, z_all: jax.Array, mask: jax.Array,
                   *, tile_n: int = DEFAULT_TILE_N,
                   tile_c: int = DEFAULT_TILE_C,
                   interpret: bool = False) -> jax.Array:
    m, n_pad, _ = a_row.shape
    c = z_all.shape[-1]
    tile_n = min(tile_n, n_pad)
    tile_c = min(tile_c, c)
    # shrink tiles to divide evenly (n_pad is 8-aligned by construction)
    while n_pad % tile_n:
        tile_n //= 2
    while c % tile_c:
        tile_c //= 2

    grid = (n_pad // tile_n, c // tile_c, m)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i, j, r: (0,)),   # block mask (SMEM)
            pl.BlockSpec((None, tile_n, n_pad), lambda i, j, r: (r, i, 0)),
            pl.BlockSpec((None, n_pad, tile_c), lambda i, j, r: (r, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_c), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c), z_all.dtype),
        scratch_shapes=[_vmem_scratch((tile_n, tile_c))],
        interpret=interpret,
    )(mask.astype(jnp.int32), a_row, z_all)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Block-compressed (ELL) variant: only the nnz blocks are materialized.
#
# The lane's neighbour blocks arrive pre-gathered in ELL form — row m holds
# its max_deg neighbour blocks plus padding — so the reduction axis is
# max_deg (~constant on power-law community graphs) instead of M.  The
# gathered feature block to multiply against is *data-dependent*
# (z_all[ell_indices[m, d]]): ``ell_indices`` is scalar-prefetched so the
# BlockSpec index_map can steer the Z DMA before the body runs, and padding
# slots (ell_mask == 0) skip the MXU work with ``@pl.when`` — the same
# predication trick as the dense kernel's absent-block skip.
#
# Ragged (size-aware) padding: two more scalar-prefetched planes,
# ``row_counts`` (k,) and ``nbr_counts`` (k, max_deg), carry each lane's
# true padded row count and each stored neighbour's.  The contraction axis
# is tiled (grid axis 4, ``tile_p``), and a tile is accumulated only when
# (a) the block is real, (b) the output row tile starts below the lane's
# row count and (c) the contraction tile starts below the neighbour's row
# count — pad rows drop out of the DMA+accumulate at tile granularity, so
# work tracks the bucketed community sizes instead of the global n_pad.
# With counts pinned at n_pad (the default) every guard is trivially live
# and the kernel is the historic global-pad program.
# ---------------------------------------------------------------------------


def _spmm_ell_kernel(idx_ref, msk_ref, rows_ref, nbr_ref, a_ref, z_ref,
                     o_ref, acc_scr, *, tile_n: int, tile_p: int):
    m = pl.program_id(0)
    i = pl.program_id(1)
    d = pl.program_id(3)
    p = pl.program_id(4)
    n_d = pl.num_programs(3)
    n_p = pl.num_programs(4)

    @pl.when((d == 0) & (p == 0))
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = ((msk_ref[m, d] != 0)
            & (i * tile_n < rows_ref[m])         # output rows are real
            & (p * tile_p < nbr_ref[m, d]))      # neighbour rows are real

    @pl.when(live)
    def _accum():
        a = a_ref[...].astype(jnp.float32)       # (tile_n, tile_p)
        z = z_ref[...].astype(jnp.float32)       # (tile_p, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when((d == n_d - 1) & (p == n_p - 1))
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "tile_p",
                                             "interpret"))
def community_spmm_ell(ell_blocks: jax.Array, ell_indices: jax.Array,
                       ell_mask: jax.Array, z_all: jax.Array,
                       row_counts: jax.Array | None = None,
                       nbr_counts: jax.Array | None = None,
                       *, tile_n: int = DEFAULT_TILE_N,
                       tile_c: int = DEFAULT_TILE_C,
                       tile_p: int | None = None,
                       interpret: bool = False) -> jax.Array:
    """Σ_d mask[m,d] · blocks[m,d] @ z_all[idx[m,d]] — O(nnz·n_pad²·C),
    and with ragged row counts O(Σ bucket_m · bucket_d · C) only.

    ell_blocks:  (k, max_deg, n_pad, n_pad) — a shard's ELL rows (f32 or
                 bf16; accumulation always f32)
    ell_indices: (k, max_deg) int32 global community ids into z_all
    ell_mask:    (k, max_deg) — nonzero = real block, 0 = padding slot
    z_all:       (M, n_pad, C) gathered community features
    row_counts:  optional (k,) int32 — lane m's padded rows; output row
                 tiles past it are skipped (written as zero)
    nbr_counts:  optional (k, max_deg) int32 — rows of each stored
                 neighbour; contraction tiles past it are skipped
    returns      (k, n_pad, C)
    """
    from jax.experimental.pallas import tpu as pltpu

    k, max_deg, n_pad, _ = ell_blocks.shape
    c = z_all.shape[-1]
    tile_n = min(tile_n, n_pad)
    tile_c = min(tile_c, c)
    tile_p = tile_n if tile_p is None else min(tile_p, n_pad)
    while n_pad % tile_n:
        tile_n //= 2
    while c % tile_c:
        tile_c //= 2
    while n_pad % tile_p:
        tile_p //= 2

    if row_counts is None:
        row_counts = jnp.full((k,), n_pad, jnp.int32)
    if nbr_counts is None:
        nbr_counts = jnp.full((k, max_deg), n_pad, jnp.int32)

    grid = (k, n_pad // tile_n, c // tile_c, max_deg, n_pad // tile_p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,     # ell_indices, ell_mask, rows, nbrs (SMEM)
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, tile_n, tile_p),
                         lambda m, i, j, d, p, idx, msk, rows, nbr:
                         (m, d, i, p)),
            pl.BlockSpec((None, tile_p, tile_c),
                         lambda m, i, j, d, p, idx, msk, rows, nbr:
                         (idx[m, d], p, j)),
        ],
        out_specs=pl.BlockSpec((None, tile_n, tile_c),
                               lambda m, i, j, d, p, idx, msk, rows, nbr:
                               (m, i, j)),
        scratch_shapes=[pltpu.VMEM((tile_n, tile_c), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_spmm_ell_kernel, tile_n=tile_n, tile_p=tile_p),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, n_pad, c), z_all.dtype),
        interpret=interpret,
    )(ell_indices.astype(jnp.int32), ell_mask.astype(jnp.int32),
      row_counts.astype(jnp.int32), nbr_counts.astype(jnp.int32),
      ell_blocks, z_all)
