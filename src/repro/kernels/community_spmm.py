"""Pallas TPU kernel: community-blocked sparse-dense matmul (Ã · Z).

The GCN ADMM hot spot is the aggregation ``Σ_r Ã_{m,r} Z_r``.  On TPU we do
NOT port a CSR gather-SpMM (no efficient per-element gather on the VPU);
instead the paper's community structure gives a *block*-sparse layout:
dense (n_pad × n_pad) community blocks with a (M × M) block mask — each
present block is a dense MXU matmul on 128-aligned VMEM tiles and absent
blocks are skipped with ``@pl.when`` (DESIGN.md §2, hardware adaptation).

Grid: (row-tiles, col-tiles, M) — the community (reduction) axis is
innermost so the output tile stays resident in VMEM across the reduction.

  a_row:  (M, n_pad, n_pad)   this shard's row of Ã blocks
  z_all:  (M, n_pad, C)       gathered community features
  mask:   (M,)                neighbour mask (True = nonzero block)
  out:    (n_pad, C)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 256     # rows per tile (8-aligned; 256 divides n_pad)
DEFAULT_TILE_C = 256     # feature cols per tile (128-aligned)


def _spmm_kernel(mask_ref, a_ref, z_ref, o_ref, acc_scr):
    r = pl.program_id(2)
    n_r = pl.num_programs(2)

    @pl.when(r == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(mask_ref[r] != 0)
    def _accum():
        a = a_ref[...]                       # (tile_n, n_pad)
        z = z_ref[...]                       # (n_pad, tile_c)
        acc_scr[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when(r == n_r - 1)
    def _write():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_c", "interpret"))
def community_spmm(a_row: jax.Array, z_all: jax.Array, mask: jax.Array,
                   *, tile_n: int = DEFAULT_TILE_N,
                   tile_c: int = DEFAULT_TILE_C,
                   interpret: bool = False) -> jax.Array:
    m, n_pad, _ = a_row.shape
    c = z_all.shape[-1]
    tile_n = min(tile_n, n_pad)
    tile_c = min(tile_c, c)
    # shrink tiles to divide evenly (n_pad is 8-aligned by construction)
    while n_pad % tile_n:
        tile_n //= 2
    while c % tile_c:
        tile_c //= 2

    grid = (n_pad // tile_n, c // tile_c, m)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i, j, r: (0,)),   # block mask (SMEM)
            pl.BlockSpec((None, tile_n, n_pad), lambda i, j, r: (r, i, 0)),
            pl.BlockSpec((None, n_pad, tile_c), lambda i, j, r: (r, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_c), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c), z_all.dtype),
        scratch_shapes=[_vmem_scratch((tile_n, tile_c))],
        interpret=interpret,
    )(mask.astype(jnp.int32), a_row, z_all)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
