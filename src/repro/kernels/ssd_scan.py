"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

One program per (batch·head, chunk); the chunk axis is innermost so the
(P × N) SSM state lives in VMEM scratch and is carried across the chunk
reduction (same persistent-scratch pattern as flash attention).  Within a
chunk everything is dense MXU work — the "dual" quadratic form of the SSD
paper: intra-chunk scores (C Bᵀ ⊙ decay), inter-chunk state injection, and
the state update, all (chunk × N/P) matmuls.

Oracle: ``repro.models.ssm.ssd_chunked`` (pure jnp, validated against the
naive recurrence in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (chunk, P)
    dt = dt_ref[0].astype(jnp.float32)        # (chunk, 1)
    a = a_ref[0, 0].astype(jnp.float32)       # scalar decay rate (< 0)
    b = b_ref[0].astype(jnp.float32)          # (chunk, N)
    c = c_ref[0].astype(jnp.float32)          # (chunk, N)

    da = dt * a                               # (chunk, 1) log-decay
    cum = jnp.cumsum(da, axis=0)              # inclusive within-chunk

    # intra-chunk dual form: scores[t,u] = (c_t·b_u)·exp(cum_t−cum_u)·dt_u
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = li >= lj
    decay = jnp.exp(cum - cum.T)              # (chunk, chunk) via broadcast
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = jnp.where(causal, scores * decay * dt.T, 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (c ⊙ exp(cum)) @ state   (state: (N, P))
    y += jax.lax.dot_general(c * jnp.exp(cum), state_scr[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: state = exp(cum_L)·state + (b ⊙ w)ᵀ @ x,
    # w_u = exp(cum_L − cum_u)·dt_u
    cum_last = cum[chunk - 1:chunk, :]        # (1, 1)
    w = jnp.exp(cum_last - cum) * dt          # (chunk, 1)
    state_scr[...] = jnp.exp(cum_last[0, 0]) * state_scr[...] + \
        jax.lax.dot_general(b * w, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b_mat: jax.Array,
             c_mat: jax.Array, *, chunk: int = 256,
             interpret: bool = False) -> tuple[jax.Array, None]:
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,G,N) -> (y, None)."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    xr = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    ar = jnp.broadcast_to(a[None, :], (bsz, h)).reshape(bsz * h, 1)
    br = b_mat.transpose(0, 2, 1, 3).reshape(bsz * g, s, n)
    cr = c_mat.transpose(0, 2, 1, 3).reshape(bsz * g, s, n)

    def bc_index(bh, ci, rep=rep, h=h, g=g):
        return (bh // h * g + (bh % h) // rep, ci, 0)

    grid = (bsz * h, nc)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, n), bc_index),
            pl.BlockSpec((1, chunk, n), bc_index),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, s, p), x.dtype),
        scratch_shapes=[_vmem_scratch((n, p))],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3), None


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
