"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def community_spmm_ref(a_row: jax.Array, z_all: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Σ_r mask_r · Ã_{m,r} Z_r — dense einsum oracle."""
    masked = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ic", masked, z_all)


def community_spmm_ell_einsum(ell_blocks: jax.Array, ell_indices: jax.Array,
                              ell_mask: jax.Array,
                              z_all: jax.Array) -> jax.Array:
    """Gather-einsum form of the ELL aggregation — the CPU dispatch path and
    the vectorized allclose target for the Pallas ELL kernel."""
    z_g = z_all[ell_indices] * ell_mask[..., None, None].astype(z_all.dtype)
    return jnp.einsum("mdip,mdpc->mic", ell_blocks, z_g)


def community_spmm_ell_ref(ell_blocks: jax.Array, ell_indices: jax.Array,
                           ell_mask: jax.Array, z_all: jax.Array) -> jax.Array:
    """Loop oracle for the block-compressed (ELL) aggregation."""
    m, max_deg = ell_indices.shape
    out = jnp.zeros((m,) + (ell_blocks.shape[2], z_all.shape[-1]),
                    z_all.dtype)
    for row in range(m):
        acc = jnp.zeros((ell_blocks.shape[2], z_all.shape[-1]), jnp.float32)
        for d in range(max_deg):
            acc += ell_mask[row, d] * (
                ell_blocks[row, d].astype(jnp.float32)
                @ z_all[ell_indices[row, d]].astype(jnp.float32))
        out = out.at[row].set(acc.astype(z_all.dtype))
    return out


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Exact softmax attention with GQA + causal/window masks (f32)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores /= jnp.sqrt(hd)
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, -2.0 ** 30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b_mat, c_mat, *, chunk: int = 256):
    """Chunked SSD oracle (validated against the naive recurrence)."""
    from repro.models.ssm import ssd_chunked
    y, _ = ssd_chunked(x, dt, a, b_mat, c_mat, min(chunk, x.shape[1]))
    return y
