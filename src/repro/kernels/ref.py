"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def community_spmm_ref(a_row: jax.Array, z_all: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Σ_r mask_r · Ã_{m,r} Z_r — dense einsum oracle."""
    masked = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ic", masked, z_all)


def community_spmm_ell_einsum(ell_blocks: jax.Array, ell_indices: jax.Array,
                              ell_mask: jax.Array, z_all: jax.Array,
                              row_counts: jax.Array | None = None,
                              nbr_counts: jax.Array | None = None
                              ) -> jax.Array:
    """Gather-einsum form of the ELL aggregation — the CPU dispatch path and
    the vectorized allclose target for the Pallas ELL kernel.

    ``row_counts`` (k,) / ``nbr_counts`` (k, max_deg) reproduce the ragged
    kernel's pad-row guards: output rows ≥ row_counts[m] and gathered Z
    rows ≥ nbr_counts[m, d] contribute nothing (they are zero in any real
    layout, so counts change no values — the guards are what lets the
    kernel *skip* the work).  Blocks may be bf16; accumulation is f32.
    """
    z_g = z_all[ell_indices] * ell_mask[..., None, None].astype(z_all.dtype)
    if nbr_counts is not None:
        lane = jnp.arange(z_all.shape[-2])
        z_g = z_g * (lane[None, None, :, None]
                     < nbr_counts[..., None, None]).astype(z_g.dtype)
    out = jnp.einsum("mdip,mdpc->mic",
                     ell_blocks.astype(jnp.float32),
                     z_g.astype(jnp.float32)).astype(z_all.dtype)
    if row_counts is not None:
        lane = jnp.arange(out.shape[-2])
        out = out * (lane[None, :, None]
                     < row_counts[:, None, None]).astype(out.dtype)
    return out


def community_spmm_ell_packed_einsum(ell_blocks: jax.Array,
                                     ell_offsets: jax.Array,
                                     ell_mask: jax.Array,
                                     z_plane: jax.Array,
                                     row_counts: jax.Array,
                                     nbr_counts: jax.Array) -> jax.Array:
    """Gather-einsum oracle for the packed-plane ELL aggregation.

    ``z_plane`` is the packed (plane_rows, C) receive plane; neighbour d
    of lane m starts at row ``ell_offsets[m, d]`` and contributes
    ``nbr_counts[m, d]`` rows.  Rows past a neighbour's count gather the
    fill value 0, so the blocked (m, d, n_pad, C) view this rebuilds is
    exactly the strided oracle's masked gather.
    """
    k, max_deg = ell_offsets.shape
    n_pad = ell_blocks.shape[2]
    lane = jnp.arange(n_pad)
    rows = ell_offsets[..., None] + lane[None, None, :]          # (k, D, n)
    valid = (lane[None, None, :] < nbr_counts[..., None]) \
        & (ell_mask[..., None] != 0)
    rows = jnp.where(valid, rows, z_plane.shape[0])              # OOB -> fill
    z_g = jnp.take(z_plane, rows.reshape(-1), axis=0, mode="fill",
                   fill_value=0)
    z_g = z_g.reshape(k, max_deg, n_pad, z_plane.shape[-1])
    out = jnp.einsum("mdip,mdpc->mic",
                     ell_blocks.astype(jnp.float32),
                     z_g.astype(jnp.float32)).astype(z_plane.dtype)
    return out * (lane[None, :, None]
                  < row_counts[:, None, None]).astype(out.dtype)


def community_spmm_ell_fused_einsum(ell_blocks: jax.Array,
                                    ell_offsets: jax.Array,
                                    ell_mask: jax.Array,
                                    z_plane: jax.Array,
                                    w: jax.Array,
                                    row_counts: jax.Array,
                                    nbr_counts: jax.Array) -> jax.Array:
    """Oracle for the fused aggregation→GEMM kernel: (A·Z)·W = A·(Z·W).

    Deliberately *reassociated*: the (C_in, C_out) weight is applied to
    the packed plane first, then the packed aggregation runs on the
    pre-multiplied plane — so the CPU-dispatch program, like the TPU
    kernel, never materialises the aggregated (k, n_pad, C_in) stack
    (the ``memory/fused-no-intermediate`` rule checks both forms of the
    compiled step).  The reassociation means parity with the unfused
    pipeline is dot-reassociation tolerance (~1e-6 at GCN widths), not
    bitwise — same contract the kernel documents.
    """
    zw = (z_plane.astype(jnp.float32)
          @ w.astype(jnp.float32)).astype(z_plane.dtype)
    return community_spmm_ell_packed_einsum(ell_blocks, ell_offsets,
                                            ell_mask, zw, row_counts,
                                            nbr_counts)


def community_spmm_ell_ref(ell_blocks: jax.Array, ell_indices: jax.Array,
                           ell_mask: jax.Array, z_all: jax.Array,
                           row_counts: jax.Array | None = None,
                           nbr_counts: jax.Array | None = None) -> jax.Array:
    """Loop oracle for the block-compressed (ELL) aggregation."""
    m, max_deg = ell_indices.shape
    n_pad = ell_blocks.shape[2]
    out = jnp.zeros((m,) + (n_pad, z_all.shape[-1]), z_all.dtype)
    lane = jnp.arange(n_pad)
    for row in range(m):
        acc = jnp.zeros((n_pad, z_all.shape[-1]), jnp.float32)
        for d in range(max_deg):
            z = z_all[ell_indices[row, d]].astype(jnp.float32)
            if nbr_counts is not None:
                z = z * (lane[:, None] < nbr_counts[row, d])
            acc += ell_mask[row, d] * (
                ell_blocks[row, d].astype(jnp.float32) @ z)
        if row_counts is not None:
            acc = acc * (lane[:, None] < row_counts[row])
        out = out.at[row].set(acc.astype(z_all.dtype))
    return out


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Exact softmax attention with GQA + causal/window masks (f32)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores /= jnp.sqrt(hd)
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, -2.0 ** 30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b_mat, c_mat, *, chunk: int = 256):
    """Chunked SSD oracle (validated against the naive recurrence)."""
    from repro.models.ssm import ssd_chunked
    y, _ = ssd_chunked(x, dt, a, b_mat, c_mat, min(chunk, x.shape[1]))
    return y
