"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def community_spmm_ref(a_row: jax.Array, z_all: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Σ_r mask_r · Ã_{m,r} Z_r — dense einsum oracle."""
    masked = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ic", masked, z_all)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Exact softmax attention with GQA + causal/window masks (f32)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores /= jnp.sqrt(hd)
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, -2.0 ** 30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b_mat, c_mat, *, chunk: int = 256):
    """Chunked SSD oracle (validated against the naive recurrence)."""
    from repro.models.ssm import ssd_chunked
    y, _ = ssd_chunked(x, dt, a, b_mat, c_mat, min(chunk, x.shape[1]))
    return y
