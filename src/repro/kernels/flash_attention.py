"""Pallas TPU kernel: flash attention (online softmax), causal + sliding
window + GQA.

Same blocking as ``models.attention.block_causal_attention`` (its jnp path
is the oracle): grid (batch·kv_head, q-blocks, kv-blocks), kv innermost so
the (block_q × head_dim) accumulator and the running (m, l) statistics stay
in VMEM scratch across the kv reduction.  Fully-masked kv blocks (beyond
the causal frontier or outside the sliding window) are skipped via
``@pl.when`` — the kernel does causal FLOPs only.

Layout per program: q (block_q, hd), k/v (block_k, hd) for one (batch,
kv-head, q-group) slice; GQA handled by folding the q-head group into the
q rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window, seq_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # causal / window block-level skip: any overlap between
    # [q_start, q_end) × [k_start, k_end)?
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)            # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (block_q, 1)
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd) -> (B, S, Hq, hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    while s % block_q:
        block_q //= 2
    while s % block_k:
        block_k //= 2

    # (B, S, Hq, hd) -> (B·Hkv, group, S, hd) -> fold group into rows
    qr = q.reshape(b, s, hkv, group, hd).transpose(0, 2, 3, 1, 4) \
          .reshape(b * hkv * group, s, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)

    grid = (b * hkv * group, s // block_q, s // block_k)
    kern = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), block_q=block_q,
        block_k=block_k, causal=causal, window=window, seq_len=s)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv * group, s, hd), q.dtype),
        scratch_shapes=[
            # VMEM scratch: running max, denominator, output accumulator
            _vmem_scratch((block_q, 1)),
            _vmem_scratch((block_q, 1)),
            _vmem_scratch((block_q, hd)),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hkv, group, s, hd).transpose(0, 3, 1, 2, 4) \
              .reshape(b, s, hq, hd)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
