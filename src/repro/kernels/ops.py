"""Jit'd kernel wrappers with backend dispatch.

On TPU the Pallas kernels run natively; on CPU (this container) the pure
jnp oracle executes instead, and tests force ``interpret=True`` Pallas to
validate the kernel bodies themselves against the oracles.

Set ``repro_force_interpret(True)`` (or env REPRO_PALLAS_INTERPRET=1) to
route the real kernels through interpret mode everywhere.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.community_spmm import community_spmm as _spmm_kernel
from repro.kernels.community_spmm import community_spmm_ell as _spmm_ell_kernel
from repro.kernels.community_spmm import (
    community_spmm_ell_fused as _spmm_ell_fused_kernel,
)
from repro.kernels.community_spmm import (
    community_spmm_ell_packed as _spmm_ell_packed_kernel,
)
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel

_FORCE_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def repro_force_interpret(value: bool) -> None:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def community_spmm(a_row: jax.Array, z_all: jax.Array,
                   mask: jax.Array | None = None) -> jax.Array:
    """Σ_r Ã_{m,r} Z_r with block-sparse skipping.

    a_row may carry a leading lane dim (k communities per shard); mask may
    then be per-lane (k, M) — each lane skips its own absent blocks — or a
    shared (M,) row."""
    if mask is None:
        mask = jnp.ones((a_row.shape[-3],), jnp.int32)
    if a_row.ndim == 4:      # lanes: vmap the kernel
        if mask.ndim == 2:   # per-lane neighbour rows
            fn = jax.vmap(lambda a, mk: community_spmm(a, z_all, mk))
            return fn(a_row, mask)
        fn = jax.vmap(lambda a: community_spmm(a, z_all, mask))
        return fn(a_row)
    if _on_tpu():
        return _spmm_kernel(a_row, z_all, mask)
    if _FORCE_INTERPRET:
        return _spmm_kernel(a_row, z_all, mask, interpret=True)
    return ref.community_spmm_ref(a_row, z_all, mask)


def community_spmm_ell(ell_blocks: jax.Array, ell_indices: jax.Array,
                       ell_mask: jax.Array, z_all: jax.Array,
                       row_counts: jax.Array | None = None,
                       nbr_counts: jax.Array | None = None) -> jax.Array:
    """Block-compressed aggregation: Σ_{d} Ã[m,d] Z[idx[m,d]] over the ELL
    view (graph.BlockCSR) — FLOPs and memory are O(nnz·n_pad²·C), not M².

    On TPU this is the lane-aware Pallas kernel (scalar-prefetched indices
    steer the Z-block DMA; padding slots are skipped with ``@pl.when``); on
    CPU the gather-einsum oracle runs instead, and tests route through the
    interpret-mode kernel body via ``repro_force_interpret``.

    ell_blocks:  (k, max_deg, n_pad, n_pad) — a shard's ELL rows (k = M on
                 the full layout, k = M/n_shards inside shard_map); f32 or
                 bf16 (CommunityData(adjacency_bf16=True)) — accumulation
                 is f32 either way
    ell_indices: (k, max_deg) int32 — global community ids into z_all
    ell_mask:    (k, max_deg) — 1 for real blocks, 0 for padding
    z_all:       (M, n_pad, C)
    row_counts:  optional (k,) — ragged layouts: lane's padded row count;
                 tiles past it skip the DMA+accumulate (graph.BlockCSR.
                 ell_row_counts)
    nbr_counts:  optional (k, max_deg) — rows each stored neighbour block
                 contributes
    returns      (k, n_pad, C)
    """
    if _on_tpu():
        return _spmm_ell_kernel(ell_blocks, ell_indices, ell_mask, z_all,
                                row_counts, nbr_counts)
    if _FORCE_INTERPRET:
        return _spmm_ell_kernel(ell_blocks, ell_indices, ell_mask, z_all,
                                row_counts, nbr_counts, interpret=True)
    return ref.community_spmm_ell_einsum(ell_blocks, ell_indices, ell_mask,
                                         z_all, row_counts, nbr_counts)


def community_spmm_ell_packed(ell_blocks: jax.Array, ell_offsets: jax.Array,
                              ell_mask: jax.Array, z_plane: jax.Array,
                              row_counts: jax.Array,
                              nbr_counts: jax.Array) -> jax.Array:
    """Packed-plane ELL aggregation: Z arrives as the packed
    Σ-bucket-rows receive plane ``(plane_rows, C)`` and the kernel reads
    each neighbour's rows through the scalar-prefetched ``ell_offsets``
    (``NeighborExchange.localized_offsets``) instead of a fixed ``n_pad``
    stride — resident gathered state is the plane, never (M, n_pad, C).

    Same dispatch contract as ``community_spmm_ell``; returns the
    blocked (k, n_pad, C) aggregate with rows past ``row_counts`` zero.
    """
    if _on_tpu():
        return _spmm_ell_packed_kernel(ell_blocks, ell_offsets, ell_mask,
                                       z_plane, row_counts, nbr_counts)
    if _FORCE_INTERPRET:
        return _spmm_ell_packed_kernel(ell_blocks, ell_offsets, ell_mask,
                                       z_plane, row_counts, nbr_counts,
                                       interpret=True)
    return ref.community_spmm_ell_packed_einsum(ell_blocks, ell_offsets,
                                                ell_mask, z_plane,
                                                row_counts, nbr_counts)


def community_spmm_ell_fused(ell_blocks: jax.Array, ell_offsets: jax.Array,
                             ell_mask: jax.Array, z_plane: jax.Array,
                             w: jax.Array,
                             row_counts: jax.Array,
                             nbr_counts: jax.Array) -> jax.Array:
    """Fused packed-plane aggregation → Z-update GEMM in one Pallas pass.

    Same operands as ``community_spmm_ell_packed`` plus the (C_in, C_out)
    weight block: the aggregated (tile_n, C_in) block stays in VMEM
    scratch and the GEMM closes the pass, so the (k, n_pad, C_in)
    aggregate never touches HBM.  The CPU oracle is the *reassociated*
    form A·(Z·W) — also aggregate-free — so every dispatch target keeps
    the no-intermediate property; parity with the unfused two-call
    pipeline is tolerance-level (dot reassociation), not bitwise.
    """
    if _on_tpu():
        return _spmm_ell_fused_kernel(ell_blocks, ell_offsets, ell_mask,
                                      z_plane, w, row_counts, nbr_counts)
    if _FORCE_INTERPRET:
        return _spmm_ell_fused_kernel(ell_blocks, ell_offsets, ell_mask,
                                      z_plane, w, row_counts, nbr_counts,
                                      interpret=True)
    return ref.community_spmm_ell_fused_einsum(ell_blocks, ell_offsets,
                                               ell_mask, z_plane, w,
                                               row_counts, nbr_counts)


def community_halo_spmm(ell_blocks: jax.Array, ell_offsets: jax.Array,
                        ell_mask: jax.Array, self_mask: jax.Array,
                        z_plane: jax.Array, row_counts: jax.Array,
                        nbr_counts: jax.Array) -> jax.Array:
    """Cross-community (halo) half of the packed ELL aggregation:
    Σ_{r∈N_m\\{m}} Ã_{m,r} Z_r — the self block is masked out of both the
    slot mask and the per-neighbour row counts, so the diagonal
    contribution never enters the contraction and the result is exactly
    the quantity the serving engine caches per (community, layer).

    ``self_mask`` is ``messages.self_slot_mask`` (1 on each row's diagonal
    slot); remaining operands and the dispatch contract (TPU Pallas /
    interpret / einsum oracle) are ``community_spmm_ell_packed``'s.
    ``halo + self-block`` reassembles the full aggregate up to float
    reassociation (the split sums the d slots in two groups) — the engine
    therefore anchors its parity guarantees on both paths running this
    same split, not on matching the one-shot contraction bitwise.
    """
    cross_mask = ell_mask * (1.0 - self_mask)
    cross_counts = (nbr_counts * (cross_mask > 0)).astype(nbr_counts.dtype)
    return community_spmm_ell_packed(ell_blocks, ell_offsets, cross_mask,
                                     z_plane, row_counts, cross_counts)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None) -> jax.Array:
    if _on_tpu():
        return _flash_kernel(q, k, v, causal=causal, window=window)
    if _FORCE_INTERPRET:
        return _flash_kernel(q, k, v, causal=causal, window=window,
                             interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = 256):
    if _on_tpu():
        return _ssd_kernel(x, dt, a, b_mat, c_mat, chunk=chunk)
    if _FORCE_INTERPRET:
        return _ssd_kernel(x, dt, a, b_mat, c_mat, chunk=chunk,
                           interpret=True)
    return ref.ssd_scan_ref(x, dt, a, b_mat, c_mat, chunk=chunk), None
