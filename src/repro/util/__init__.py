from repro.util.compat import shard_map  # noqa: F401
