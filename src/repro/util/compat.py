"""Version-compat shims (jax.shard_map moved out of experimental in 0.8)."""
from __future__ import annotations

import jax


def make_mesh(shape, names, devices=None):
    """jax.make_mesh with explicit Auto axis types (silences the 0.9
    default-change warning; we rely on Auto sharding propagation)."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, names,
                             axis_types=(AxisType.Auto,) * len(names),
                             devices=devices)
    except (ImportError, TypeError):
        return jax.make_mesh(shape, names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False,
              axis_names=None):
    """jax.shard_map across jax versions (check_vma vs check_rep naming).

    ``axis_names``: mesh axes the body is MANUAL over (others stay auto —
    partial-manual mode, used by the deferred-grad-reduction train step)."""
    kwargs = {}
    if axis_names is not None:
        kwargs["axis_names"] = frozenset(axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep, **kwargs)
