"""Composable transformer stacks for all assigned architecture families.

A model is a list of **segments**: (kind, count).  Per-layer params are
stacked along a leading ``count`` axis and the forward pass is a
``lax.scan`` over that axis (one trace per segment — compile time stays
O(#kinds), not O(#layers)), optionally rematerialized.  The stacked layer
axis is also what the generic layerwise-ADMM trainer shards over 'model'
(the paper's layer parallelism as axis sharding — DESIGN.md §3).

Segment kinds:
  attn_mlp    pre-norm attention (GQA/MQA/MLA per cfg) + dense FFN
  attn_moe    attention + MoE FFN (shared + routed experts)
  ssm         Mamba-2 SSD mixer (no FFN)
  hybrid      one (rglru, rglru, local-attn) period, each with FFN
  rglru_mlp   single RG-LRU block + FFN (hybrid tail layers)
  enc         bidirectional encoder layer (enc-dec archs)
  dec         causal self-attn + cross-attn + FFN decoder layer
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe as moe_lib, rglru, ssm
from repro.models.layers import Params

Array = jax.Array


# ---------------------------------------------------------------------------
# segment plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int


def arch_segments(cfg: ModelConfig) -> list[Segment]:
    if cfg.is_encoder_decoder:
        return [Segment("enc", cfg.num_layers),
                Segment("dec", cfg.num_decoder_layers)]
    if cfg.arch_type == "ssm":
        return [Segment("ssm", cfg.num_layers)]
    if cfg.hybrid is not None:
        period = len(cfg.hybrid.pattern)
        n_periods, tail = divmod(cfg.num_layers, period)
        segs = [Segment("hybrid", n_periods)]
        if tail:
            segs.append(Segment("rglru_mlp", tail))
        return segs
    if cfg.moe is not None:
        segs = []
        if cfg.moe.first_dense_layers:
            segs.append(Segment("attn_mlp", cfg.moe.first_dense_layers))
        segs.append(Segment("attn_moe",
                            cfg.num_layers - cfg.moe.first_dense_layers))
        return segs
    return [Segment("attn_mlp", cfg.num_layers)]


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _dense_ff_width(cfg: ModelConfig) -> int:
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        return cfg.moe.dense_d_ff or cfg.d_ff
    return cfg.d_ff


def init_layer(cfg: ModelConfig, kind: str, key) -> Params:
    ks = jax.random.split(key, 8)
    if kind == "attn_mlp":
        return {
            "norm1": layers.init_norm(cfg, cfg.d_model),
            "attn": attention.init_attention(cfg, ks[0]),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "mlp": layers.init_mlp(cfg, ks[1], cfg.d_model,
                                   _dense_ff_width(cfg)),
        }
    if kind == "attn_moe":
        return {
            "norm1": layers.init_norm(cfg, cfg.d_model),
            "attn": attention.init_attention(cfg, ks[0]),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "moe": moe_lib.init_moe(cfg, ks[1]),
        }
    if kind == "ssm":
        return {
            "norm": layers.init_norm(cfg, cfg.d_model),
            "mixer": ssm.init_ssm(cfg, ks[0]),
        }
    if kind == "hybrid":
        p: Params = {}
        for i, blk in enumerate(cfg.hybrid.pattern):
            sub = {
                "norm1": layers.init_norm(cfg, cfg.d_model),
                "norm2": layers.init_norm(cfg, cfg.d_model),
                "mlp": layers.init_mlp(cfg, ks[2 * i + 1], cfg.d_model,
                                       cfg.d_ff),
            }
            if blk == "rglru":
                sub["rg"] = rglru.init_rglru_block(cfg, ks[2 * i])
            else:
                sub["attn"] = attention.init_attention(cfg, ks[2 * i])
            p[f"blk{i}"] = sub
        return p
    if kind == "rglru_mlp":
        return {
            "norm1": layers.init_norm(cfg, cfg.d_model),
            "rg": rglru.init_rglru_block(cfg, ks[0]),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "mlp": layers.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff),
        }
    if kind == "enc":
        return {
            "norm1": layers.init_norm(cfg, cfg.d_model),
            "attn": attention.init_attention(cfg, ks[0]),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "mlp": layers.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff),
        }
    if kind == "dec":
        return {
            "norm1": layers.init_norm(cfg, cfg.d_model),
            "attn": attention.init_attention(cfg, ks[0]),
            "norm_x": layers.init_norm(cfg, cfg.d_model),
            "cross": attention.init_cross_attention(cfg, ks[1]),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "mlp": layers.init_mlp(cfg, ks[2], cfg.d_model, cfg.d_ff),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# per-layer forward (full sequence)
# ---------------------------------------------------------------------------

def _attn_fwd(cfg: ModelConfig, p: Params, x: Array, *, causal=True,
              window=None) -> Array:
    if cfg.mla is not None:
        return attention.mla_forward(cfg, p, x, window=window)
    return attention.gqa_forward(cfg, p, x, causal=causal, window=window)


def apply_layer(cfg: ModelConfig, kind: str, p: Params, x: Array, *,
                window: Optional[int] = None,
                memory: Optional[Array] = None,
                use_kernel: bool = False) -> tuple[Array, Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "enc"):
        causal = kind != "enc"
        x = x + _attn_fwd(cfg, p["attn"],
                          layers.apply_norm(cfg, p["norm1"], x),
                          causal=causal, window=window)
        x = x + layers.apply_mlp(cfg, p["mlp"],
                                 layers.apply_norm(cfg, p["norm2"], x))
    elif kind == "attn_moe":
        x = x + _attn_fwd(cfg, p["attn"],
                          layers.apply_norm(cfg, p["norm1"], x),
                          window=window)
        h, aux = moe_lib.apply_moe(cfg, p["moe"],
                                   layers.apply_norm(cfg, p["norm2"], x))
        x = x + h
    elif kind == "ssm":
        x = x + ssm.ssm_forward(cfg, p["mixer"],
                                layers.apply_norm(cfg, p["norm"], x),
                                use_kernel=use_kernel)
    elif kind == "hybrid":
        for i, blk in enumerate(cfg.hybrid.pattern):
            sub = p[f"blk{i}"]
            h_in = layers.apply_norm(cfg, sub["norm1"], x)
            if blk == "rglru":
                x = x + rglru.rglru_block_forward(cfg, sub["rg"], h_in)
            else:
                x = x + attention.gqa_forward(
                    cfg, sub["attn"], h_in, causal=True,
                    window=cfg.hybrid.local_window)
            x = x + layers.apply_mlp(cfg, sub["mlp"],
                                     layers.apply_norm(cfg, sub["norm2"], x))
    elif kind == "rglru_mlp":
        x = x + rglru.rglru_block_forward(
            cfg, p["rg"], layers.apply_norm(cfg, p["norm1"], x))
        x = x + layers.apply_mlp(cfg, p["mlp"],
                                 layers.apply_norm(cfg, p["norm2"], x))
    elif kind == "dec":
        x = x + _attn_fwd(cfg, p["attn"],
                          layers.apply_norm(cfg, p["norm1"], x),
                          window=window)
        x = x + attention.gqa_cross_forward(
            cfg, p["cross"], layers.apply_norm(cfg, p["norm_x"], x), memory)
        x = x + layers.apply_mlp(cfg, p["mlp"],
                                 layers.apply_norm(cfg, p["norm2"], x))
    else:
        raise ValueError(kind)
    return x, aux


# ---------------------------------------------------------------------------
# per-layer decode step (one token against the layer's cache)
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     rolling: bool, memory_len: int = 0) -> Params:
    if kind in ("attn_mlp", "attn_moe"):
        if cfg.mla is not None:
            return attention.init_mla_cache(cfg, batch, max_len)
        return attention.init_gqa_cache(cfg, batch, max_len, rolling=rolling)
    if kind == "ssm":
        return ssm.init_ssm_cache(cfg, batch)
    if kind == "hybrid":
        c: Params = {}
        for i, blk in enumerate(cfg.hybrid.pattern):
            if blk == "rglru":
                c[f"blk{i}"] = rglru.init_rglru_cache(cfg, batch)
            else:
                c[f"blk{i}"] = attention.init_gqa_cache(
                    cfg, batch, min(max_len, cfg.hybrid.local_window),
                    rolling=True)
        return c
    if kind == "rglru_mlp":
        return rglru.init_rglru_cache(cfg, batch)
    if kind == "dec":
        hd = cfg.resolved_head_dim
        dt = layers.dtype_of(cfg)
        return {
            "self": attention.init_gqa_cache(cfg, batch, max_len,
                                             rolling=rolling),
            "cross_k": jnp.zeros((batch, memory_len, cfg.num_kv_heads, hd),
                                 dt),
            "cross_v": jnp.zeros((batch, memory_len, cfg.num_kv_heads, hd),
                                 dt),
        }
    raise ValueError(kind)


def apply_layer_step(cfg: ModelConfig, kind: str, p: Params, cache: Params,
                     x_t: Array, *, rolling: bool = False
                     ) -> tuple[Array, Params]:
    if kind in ("attn_mlp", "attn_moe"):
        h_in = layers.apply_norm(cfg, p["norm1"], x_t)
        if cfg.mla is not None:
            h, cache = attention.mla_decode_step(cfg, p["attn"], cache, h_in)
        else:
            h, cache = attention.gqa_decode_step(cfg, p["attn"], cache, h_in,
                                                 rolling=rolling)
        x_t = x_t + h
        h_in = layers.apply_norm(cfg, p["norm2"], x_t)
        if kind == "attn_mlp":
            x_t = x_t + layers.apply_mlp(cfg, p["mlp"], h_in)
        else:
            h, _ = moe_lib.apply_moe(cfg, p["moe"], h_in)
            x_t = x_t + h
        return x_t, cache
    if kind == "ssm":
        h_in = layers.apply_norm(cfg, p["norm"], x_t)
        h, cache = ssm.ssm_decode_step(cfg, p["mixer"], cache, h_in)
        return x_t + h, cache
    if kind == "hybrid":
        new_c: Params = {}
        for i, blk in enumerate(cfg.hybrid.pattern):
            sub = p[f"blk{i}"]
            h_in = layers.apply_norm(cfg, sub["norm1"], x_t)
            if blk == "rglru":
                h, new_c[f"blk{i}"] = rglru.rglru_block_step(
                    cfg, sub["rg"], cache[f"blk{i}"], h_in)
            else:
                h, new_c[f"blk{i}"] = attention.gqa_decode_step(
                    cfg, sub["attn"], cache[f"blk{i}"], h_in, rolling=True)
            x_t = x_t + h
            x_t = x_t + layers.apply_mlp(
                cfg, sub["mlp"], layers.apply_norm(cfg, sub["norm2"], x_t))
        return x_t, new_c
    if kind == "rglru_mlp":
        h_in = layers.apply_norm(cfg, p["norm1"], x_t)
        h, cache = rglru.rglru_block_step(cfg, p["rg"], cache, h_in)
        x_t = x_t + h
        x_t = x_t + layers.apply_mlp(cfg, p["mlp"],
                                     layers.apply_norm(cfg, p["norm2"], x_t))
        return x_t, cache
    if kind == "dec":
        h_in = layers.apply_norm(cfg, p["norm1"], x_t)
        h, self_c = attention.gqa_decode_step(cfg, p["attn"], cache["self"],
                                              h_in, rolling=rolling)
        x_t = x_t + h
        # cross-attention against the precomputed memory k/v
        h_in = layers.apply_norm(cfg, p["norm_x"], x_t)
        hd = cfg.resolved_head_dim
        b = x_t.shape[0]
        q = (h_in @ p["cross"]["q"]).reshape(b, 1, cfg.num_heads, hd)
        h = attention._sdpa(q, cache["cross_k"], cache["cross_v"], None)
        x_t = x_t + h.reshape(b, 1, -1) @ p["cross"]["o"]
        x_t = x_t + layers.apply_mlp(cfg, p["mlp"],
                                     layers.apply_norm(cfg, p["norm2"], x_t))
        return x_t, {"self": self_c, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stacked-segment init / forward / decode
# ---------------------------------------------------------------------------

def init_stack(cfg: ModelConfig, key) -> Params:
    segs = arch_segments(cfg)
    params: Params = {}
    for seg in segs:
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, seg.count)
        params[seg.kind] = jax.vmap(partial(init_layer, cfg, seg.kind))(keys)
    return params


def apply_stack(cfg: ModelConfig, params: Params, x: Array, *,
                window: Optional[int] = None,
                memory: Optional[Array] = None,
                use_kernel: bool = False,
                only_kinds: Optional[tuple[str, ...]] = None
                ) -> tuple[Array, Array]:
    """Scan each segment's stacked layers. Returns (x, total_aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    for seg in arch_segments(cfg):
        if only_kinds is not None and seg.kind not in only_kinds:
            continue
        def body(carry, layer_p, kind=seg.kind):
            from repro.sharding import hints
            carry = hints.hint_residual(carry)
            h, aux = apply_layer(cfg, kind, layer_p, carry, window=window,
                                 memory=memory, use_kernel=use_kernel)
            return h, aux
        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, params[seg.kind])
        aux_total = aux_total + auxs.sum()
    return x, aux_total


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                     rolling: bool, memory_len: int = 0) -> Params:
    caches: Params = {}
    for seg in arch_segments(cfg):
        if seg.kind == "enc":        # encoder has no decode step
            continue
        one = init_layer_cache(cfg, seg.kind, batch, max_len, rolling,
                               memory_len)
        caches[seg.kind] = jax.tree.map(
            lambda l: jnp.zeros((seg.count,) + l.shape, l.dtype), one)
        # slot_pos must start at -1 (invalid), not 0
        caches[seg.kind] = jax.tree_util.tree_map_with_path(
            lambda path, l: jnp.full_like(l, -1)
            if any(getattr(k, "key", None) == "slot_pos" for k in path)
            else l, caches[seg.kind])
    return caches


def decode_stack(cfg: ModelConfig, params: Params, caches: Params,
                 x_t: Array, *, rolling: bool = False
                 ) -> tuple[Array, Params]:
    new_caches: Params = {}
    for seg in arch_segments(cfg):
        if seg.kind == "enc":
            continue
        def body(carry, xs, kind=seg.kind):
            layer_p, layer_c = xs
            h, new_c = apply_layer_step(cfg, kind, layer_p, layer_c, carry,
                                        rolling=rolling)
            return h, new_c
        x_t, new_caches[seg.kind] = jax.lax.scan(
            body, x_t, (params[seg.kind], caches[seg.kind]))
    return x_t, new_caches
