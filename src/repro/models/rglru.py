"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrent block: two input branches (gate: GeLU; signal: conv1d → RG-LRU),
elementwise merge, output projection.  RG-LRU:

    r_t = σ(W_a x_t + b_a)            recurrence gate (block-diagonal W)
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = exp(−c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Sequence form uses ``jax.lax.associative_scan`` (log-depth on TPU);
decode is the O(1) per-token recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params, dense_init, dtype_of

Array = jax.Array

N_DIAG_BLOCKS = 8


def width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def init_rglru_block(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg)
    w = width(cfg)
    bs = w // N_DIAG_BLOCKS
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w)) / cfg.hybrid.lru_c))
    return {
        "in_x": dense_init(ks[0], (cfg.d_model, w), dt),
        "in_gate": dense_init(ks[1], (cfg.d_model, w), dt),
        "conv": layers.init_conv(cfg, ks[2], w, cfg.hybrid.conv_kernel),
        "gate_a": dense_init(ks[3], (N_DIAG_BLOCKS, bs, bs), dt),
        "gate_a_b": jnp.zeros((w,), jnp.float32),
        "gate_x": dense_init(ks[4], (N_DIAG_BLOCKS, bs, bs), dt),
        "gate_x_b": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "out": dense_init(ks[5], (w, cfg.d_model), dt),
    }


def _block_diag(gate_w: Array, x: Array) -> Array:
    """x: (..., W) through block-diagonal weight (NB, bs, bs)."""
    nb, bs, _ = gate_w.shape
    xb = x.reshape(x.shape[:-1] + (nb, bs))
    out = jnp.einsum("...nb,nbc->...nc", xb, gate_w)
    return out.reshape(x.shape)


def _rglru_gates(cfg: ModelConfig, p: Params, x: Array):
    """Returns (log_a, scaled_input): h_t = exp(log_a)h + √(1−a²)(i·x)."""
    r = jax.nn.sigmoid(_block_diag(p["gate_a"], x).astype(jnp.float32)
                       + p["gate_a_b"])
    i = jax.nn.sigmoid(_block_diag(p["gate_x"], x).astype(jnp.float32)
                       + p["gate_x_b"])
    log_a = -cfg.hybrid.lru_c * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    scaled = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i * x.astype(jnp.float32))
    return log_a, scaled


def rglru_scan(cfg: ModelConfig, p: Params, x: Array,
               h0: Array | None = None) -> tuple[Array, Array]:
    """Linear recurrence over (B, S, W) via associative scan."""
    log_a, scaled = _rglru_gates(cfg, p, x)
    a = jnp.exp(log_a)
    if h0 is not None:
        scaled = scaled.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, scaled), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block_forward(cfg: ModelConfig, p: Params, x: Array) -> Array:
    """(B, S, D) -> (B, S, D)."""
    gate = jax.nn.gelu(x @ p["in_gate"], approximate=True)
    sig = x @ p["in_x"]
    sig = layers.apply_conv(p["conv"], sig)
    h, _ = rglru_scan(cfg, p, sig)
    return (h * gate) @ p["out"]


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Params:
    dt = dtype_of(cfg)
    w = width(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.hybrid.conv_kernel - 1, w), dt),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_block_step(cfg: ModelConfig, p: Params, cache: Params,
                     x_t: Array) -> tuple[Array, Params]:
    """One decode token: x_t (B, 1, D)."""
    xt = x_t[:, 0, :]
    gate = jax.nn.gelu(xt @ p["in_gate"], approximate=True)
    sig = xt @ p["in_x"]
    sig, conv_state = layers.apply_conv_step(p["conv"], cache["conv"], sig)
    log_a, scaled = _rglru_gates(cfg, p, sig)
    h = jnp.exp(log_a) * cache["h"] + scaled
    out = ((h.astype(xt.dtype) * gate) @ p["out"])[:, None, :]
    return out, {"conv": conv_state, "h": h}
