"""Mixture-of-Experts FFN: shared + routed experts, top-k, capacity dispatch.

Dispatch is scatter-based (no (T, E, C) one-hot einsum): tokens are ranked
within their expert by a cumulative-count over the top-k assignment matrix,
dropped beyond capacity, and scattered into per-expert buffers (E, C, D).
Expert weights carry a leading E axis that shards over the ``model`` mesh
axis (expert parallelism); under pjit the scatter/gather lowers to the
all-to-all-style collectives the roofline's collective term measures.

Matches DeepSeekMoE (arXiv:2401.06066) / DeepSeek-V3 (arXiv:2412.19437)
structure: fine-grained experts + shared experts + aux load-balance loss.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params, dense_init, dtype_of

Array = jax.Array


def init_moe(cfg: ModelConfig, key) -> Params:
    moe = cfg.moe
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.num_experts

    def stack_init(k, shape):
        return dense_init(k, shape, dt, scale=1.0 / jnp.sqrt(shape[-2]))

    p: Params = {
        "router": dense_init(ks[0], (d, e), jnp.float32),  # f32 router
        "w_gate": stack_init(ks[1], (e, d, f)),
        "w_up": stack_init(ks[2], (e, d, f)),
        "w_down": stack_init(ks[3], (e, f, d)),
    }
    if moe.num_shared_experts:
        p["shared"] = layers.init_mlp(
            cfg, ks[4], d, moe.num_shared_experts * f)
    return p


def _expert_ffn(cfg: ModelConfig, p: Params, xs: Array) -> Array:
    """xs: (E, C, D) -> (E, C, D), vectorized over the expert axis."""
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else \
            lambda v: jax.nn.gelu(v, approximate=True)
        h = act(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xs, p["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xs, p["w_up"]),
                        approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def apply_moe(cfg: ModelConfig, p: Params, x: Array
              ) -> tuple[Array, Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Dispatch path selection: when sharding hints are active with
    ``moe_a2a`` and the expert count divides the 'model' axis, the
    explicit expert-parallel all-to-all dispatch runs (apply_moe_a2a);
    otherwise the portable scatter-based path below."""
    from repro.sharding import hints
    mesh = hints.active_mesh()
    if (hints.moe_a2a_enabled() and mesh is not None
            and "model" in mesh.axis_names
            and cfg.moe.num_experts % mesh.shape["model"] == 0
            and cfg.moe.num_experts >= mesh.shape["model"]
            and not _inside_manual_region()):
        return apply_moe_a2a(cfg, p, x, mesh)
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    flat_expert = expert_ids.reshape(t * k)

    # load-balance aux loss (Switch-style): E * Σ_e f_e · p̄_e
    counts = jnp.zeros((e,), jnp.float32).at[flat_expert].add(1.0)
    frac_tokens = counts / (t * k)
    frac_probs = probs.mean(0)
    aux = moe.router_aux_weight * e * jnp.vdot(frac_tokens, frac_probs)

    # capacity floor of min(T·k, 16) keeps tiny (decode-sized) batches
    # effectively drop-free — binomial overflow beyond 16 slots at T·k/E
    # expected load is negligible, and cached decode must reproduce the
    # full forward (tests/test_decode_consistency.py)
    capacity = max(int(t * k / e * moe.capacity_factor), min(t * k, 32))

    # rank each (token, slot) within its expert via a stable sort — O(T·k)
    # memory (no (T·k, E) one-hot buffer)
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_experts = flat_expert[sort_idx]
    idx = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_experts[1:] != sorted_experts[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    rank_sorted = idx - group_start
    rank = jnp.zeros((t * k,), jnp.int32).at[sort_idx].set(rank_sorted)
    keep = rank < capacity

    # scatter tokens into (E, C, D) buffers via masked scatter-ADD: every
    # kept (token, slot) owns a unique rank < capacity, so add == set, and
    # dropped tokens contribute zero — no trash row, so the buffer shape
    # stays exactly (E·C, D) and can be pinned to the expert ('model') axis
    # from creation (the scatter then lowers as an all-to-all instead of a
    # replicated scatter + reshard; see EXPERIMENTS.md §Perf).
    from repro.sharding import hints
    slot = flat_expert * capacity + jnp.minimum(rank, capacity - 1)
    src = jnp.repeat(hints.hint_tokens(xf), k, axis=0)       # (T*k, D)
    src = src * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e * capacity, d), x.dtype)
    buf, _ = hints.hint_moe_buffers(buf, buf)
    buf = buf.at[slot].add(src)
    expert_in = buf.reshape(e, capacity, d)

    expert_in, _ = hints.hint_moe_buffers(expert_in, expert_in)
    expert_out = _expert_ffn(cfg, p, expert_in)              # (E, C, D)
    expert_out, _ = hints.hint_moe_buffers(expert_out, expert_out)

    # gather back and weight by (renormalized, drop-masked) gates
    flat_out = expert_out.reshape(e * capacity, d)
    gathered = flat_out[slot]                                # (T*k, D)
    gates = (gate_vals.reshape(t * k) * keep).astype(x.dtype)
    combined = (gathered * gates[:, None]).reshape(t, k, d).sum(1)

    if moe.num_shared_experts:
        combined = combined + layers.apply_mlp(cfg, p["shared"], xf)
    return combined.reshape(b, s, d), aux


def _inside_manual_region() -> bool:
    """True when tracing inside an enclosing shard_map (e.g. the deferred-
    reduction train step is manual over the data axes) — nesting another
    shard_map over the same mesh there is invalid, so the a2a path defers
    to the portable dispatch."""
    try:
        am = jax.sharding.get_abstract_mesh()
        from jax.sharding import AxisType
        return any(t == AxisType.Manual
                   for t in getattr(am, "axis_types", ()))
    except Exception:
        return False


# ---------------------------------------------------------------------------
# explicit expert-parallel all-to-all dispatch (§Perf pair-2 iteration 4)
# ---------------------------------------------------------------------------

def apply_moe_a2a(cfg: ModelConfig, p: Params, x: Array, mesh
                  ) -> tuple[Array, Array]:
    """GShard-style MoE: tokens are locally packed into per-expert slots,
    exchanged with ONE all-to-all over the 'model' (expert) axis, run
    through the local expert shard, and returned with the reverse
    all-to-all — the collective volume is the dispatch floor
    (tokens × top_k × D × 2 directions) instead of the replicated
    scatter + all-reduce XLA derives from the portable path.

    shard_map is manual over BOTH the data axes (tokens stay local to
    their shard — routing/sort/pack are per-shard) and 'model' (experts).
    A first attempt manual over 'model' only forced global-token semantics
    (XLA materialized global sorts + gathers) and REGRESSED 14× — see
    EXPERIMENTS.md §Perf pair 2 iteration 4.  Because the data axes are
    manual here, this path is enabled for prefill/decode (plain jit); the
    deferred-reduction train step is already manual over data at an outer
    level and keeps the portable path.
    """
    from repro.util import shard_map as _shard_map
    from jax.sharding import PartitionSpec as P

    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    nm = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(xf, router, w_gate, w_up, w_down, shared):
        # manual over data axes AND 'model': xf (T_loc, D) is this data
        # shard's tokens (replicated over 'model'); w_* the local expert
        # shard (E/nm, ...) replicated over data
        t = xf.shape[0]
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
        flat_expert = expert_ids.reshape(t * k)

        counts = jnp.zeros((e,), jnp.float32).at[flat_expert].add(1.0)
        aux = moe.router_aux_weight * e * jnp.vdot(
            counts / (t * k), probs.mean(0))
        # average the load-balance statistic across all token shards
        aux = jax.lax.pmean(aux, dp + ("model",)) if dp else \
            jax.lax.pmean(aux, "model")

        capacity = max(int(t * k / e * moe.capacity_factor),
                       min(t * k, 32))
        sort_idx = jnp.argsort(flat_expert, stable=True)
        sorted_experts = flat_expert[sort_idx]
        idx = jnp.arange(t * k, dtype=jnp.int32)
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool),
             sorted_experts[1:] != sorted_experts[:-1]])
        group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
        rank = jnp.zeros((t * k,), jnp.int32).at[sort_idx].set(
            idx - group_start)
        keep = rank < capacity

        slot = flat_expert * capacity + jnp.minimum(rank, capacity - 1)
        src = jnp.repeat(xf, k, axis=0) * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((e * capacity, d), xf.dtype).at[slot].add(src)
        buf = buf.reshape(e, capacity, d)

        # THE dispatch: experts split over 'model', capacities concatenated
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)          # (E/nm, C·nm, D)

        if cfg.mlp in ("swiglu", "geglu"):
            act = jax.nn.silu if cfg.mlp == "swiglu" else \
                lambda v: jax.nn.gelu(v, approximate=True)
            h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
                jnp.einsum("ecd,edf->ecf", buf, w_up)
        elif cfg.mlp == "relu2":
            h = jnp.square(jax.nn.relu(
                jnp.einsum("ecd,edf->ecf", buf, w_up)))
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, w_up),
                            approximate=True)
        out = jnp.einsum("ecf,efd->ecd", h, w_down)   # (E/nm, C·nm, D)

        # return trip + local combine
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)          # (E, C, D)
        flat_out = out.reshape(e * capacity, d)
        gathered = flat_out[slot]
        gates = (gate_vals.reshape(t * k) * keep).astype(xf.dtype)
        combined = (gathered * gates[:, None]).reshape(t, k, d).sum(1)
        if moe.num_shared_experts:
            combined = combined + layers.apply_mlp(cfg, shared, xf)
        return combined, aux

    xf = x.reshape(b * s, d)
    shared = p.get("shared", {"up": jnp.zeros((0,)),
                              "down": jnp.zeros((0,))})
    rep2 = P(None, None)
    # tokens split over the data axes AND 'model' — every device routes a
    # distinct token slice (replicating tokens over 'model' would dispatch
    # nm identical copies: 16x redundant expert compute + a2a volume,
    # measured as §Perf pair-2 iteration 5's first attempt)
    t_axes = dp + ("model",)
    n_split = _dp_size(mesh) * nm
    tok = P(t_axes if (b * s) % n_split == 0 else
            (dp if (b * s) % _dp_size(mesh) == 0 else None), None)
    out, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(tok, rep2, P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  jax.tree.map(lambda _: rep2, shared)),
        out_specs=(tok, P()),
        check_rep=False, axis_names=dp + ("model",))(
        xf, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
    return out.reshape(b, s, d), aux


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
