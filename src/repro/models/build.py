"""Top-level Model API: init / train_step / prefill / decode_step /
input_specs — the single entry point used by the launcher, the dry-run and
the smoke tests.

Batch formats (input_specs returns matching ShapeDtypeStructs):
  text archs   {'tokens': (B,S) i32, 'targets': (B,S) i32}
  vlm          + 'vision_embeds': (B,P,D)   (stub frontend, DESIGN.md)
  audio encdec {'frames': (B,S_enc,D), 'tokens': (B,S_dec), 'targets': ...}

Decode runs ONE token against a cache of ``max_len`` (the assigned decode
shapes); ``rolling=True`` selects the sliding-window rolling cache used by
``long_500k`` on attention archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import layers, transformer
from repro.models.layers import Params
from repro.optim import optimizers

Array = jax.Array

# vision prefix length comes from cfg.frontend.num_embeddings (stub ViT)
AUDIO_MEMORY = 1536        # encoder frames held as decode memory
DEC_FRACTION = 8           # enc-dec training: dec_len = seq_len // 8


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init

    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_stack, k_norm, k_mtp, k_enc_emb = jax.random.split(key, 5)
        params: Params = {
            "embedding": layers.init_embedding(cfg, k_emb),
            "stack": transformer.init_stack(cfg, k_stack),
            "final_norm": layers.init_norm(cfg, cfg.d_model),
        }
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": layers.dense_init(
                    k_mtp, (2 * cfg.d_model, cfg.d_model),
                    layers.dtype_of(cfg)),
                "layer": transformer.init_layer(cfg, "attn_mlp", k_mtp),
                "norm": layers.init_norm(cfg, cfg.d_model),
            }
        if cfg.is_encoder_decoder:
            params["enc_final_norm"] = layers.init_norm(cfg, cfg.d_model)
        return params

    def init_optimizer(self):
        return optimizers.make(self.cfg.optimizer, self.cfg.learning_rate)

    # --------------------------------------------------------------- forward

    def _embed_inputs(self, params: Params, batch: dict) -> Array:
        x = layers.embed(params["embedding"], batch["tokens"])
        if self.cfg.arch_type == "vlm":
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(x.dtype), x], axis=1)
        return x

    def forward(self, params: Params, batch: dict, *,
                window: Optional[int] = None,
                use_kernel: bool = False,
                last_only: bool = False) -> tuple[Array, Array, Array]:
        """Full forward. Returns (logits, aux_loss, hidden).

        ``last_only`` restricts the unembed to the final position (prefill:
        avoids materializing the (B, S, V) logits buffer)."""
        cfg = self.cfg
        window = window if window is not None else cfg.sliding_window
        memory = None
        if cfg.is_encoder_decoder:
            memory = self.encode(params, batch["frames"],
                                 use_kernel=use_kernel)
        x = self._embed_inputs(params, batch)
        only = ("dec",) if cfg.is_encoder_decoder else None
        x, aux = transformer.apply_stack(cfg, params["stack"], x,
                                         window=window, memory=memory,
                                         use_kernel=use_kernel,
                                         only_kinds=only)
        h = layers.apply_norm(cfg, params["final_norm"], x)
        if cfg.arch_type == "vlm":
            h = h[:, self.cfg.frontend.num_embeddings:]
        logits = layers.unembed(cfg, params["embedding"],
                                h[:, -1:] if last_only else h)
        return logits, aux, h

    def encode(self, params: Params, frames: Array,
               use_kernel: bool = False) -> Array:
        """Encoder over stubbed frame embeddings (enc-dec archs)."""
        cfg = self.cfg

        # only the 'enc' segment runs here
        def body(carry, layer_p):
            h, _ = transformer.apply_layer(cfg, "enc", layer_p, carry)
            return h, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, frames, params["stack"]["enc"])
        return layers.apply_norm(cfg, params["enc_final_norm"], x)

    # ----------------------------------------------------------------- loss

    def loss(self, params: Params, batch: dict) -> tuple[Array, dict]:
        logits, aux, h = self.forward(params, batch)
        ce = _next_token_ce(logits, batch["targets"])
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if self.cfg.mtp_depth:
            mtp_ce = self._mtp_loss(params, h, batch)
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        return total, metrics

    def _mtp_loss(self, params: Params, h: Array, batch: dict) -> Array:
        """DeepSeek-V3 multi-token prediction: one extra block predicts
        token t+2 from [h_t ; emb(target_t)]."""
        cfg = self.cfg
        emb = layers.embed(params["embedding"], batch["targets"])
        x = jnp.concatenate([h, emb.astype(h.dtype)], axis=-1) \
            @ params["mtp"]["proj"]
        x, _ = transformer.apply_layer(cfg, "attn_mlp",
                                       params["mtp"]["layer"], x)
        x = layers.apply_norm(cfg, params["mtp"]["norm"], x)
        logits = layers.unembed(cfg, params["embedding"], x[:, :-1])
        return _next_token_ce(logits, batch["targets"][:, 1:])

    # ------------------------------------------------------------ train step

    def train_step(self, params: Params, opt_state, batch: dict):
        """One optimizer step; with cfg.grad_accum > 1 the global batch is
        split into microbatches scanned with gradient accumulation (keeps
        activation memory ~1/A per chip — the standard large-model recipe)."""
        opt = self.init_optimizer()
        accum = self.cfg.grad_accum
        if accum <= 1:
            (loss_val, metrics), grads = jax.value_and_grad(
                self.loss, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def micro_step(carry, mb):
                grads_acc, loss_acc = carry
                (lv, mets), g = jax.value_and_grad(
                    self.loss, has_aux=True)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), grads_acc, g)
                return (grads_acc, loss_acc + lv), mets

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss_sum), mets = jax.lax.scan(
                micro_step, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss_val = loss_sum / accum
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda w, u: w + u.astype(w.dtype),
                              params, updates)
        metrics = dict(metrics, loss=loss_val)
        return params, opt_state, metrics

    def train_step_deferred(self, mesh, params: Params, opt_state,
                            batch: dict):
        """§Perf optimization: gradient accumulation with DEFERRED data-
        parallel reduction.

        The plain ``train_step`` lets XLA make the grad-accum scan carry
        replicated across 'data', which inserts a full gradient all-reduce
        *inside every microbatch iteration* (visible in the baseline HLO
        census).  Here the data axes are manual (shard_map): each data
        shard accumulates its LOCAL grads across microbatches, and a single
        psum runs after the scan — collective volume drops by ~grad_accum×.
        The 'model' axis stays auto, so tensor-parallel sharding inside the
        loss is unchanged.
        """
        from repro.util import shard_map as _shard_map
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        accum = max(self.cfg.grad_accum, 1)
        opt = self.init_optimizer()

        def per_shard(params, batch_shard):
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch_shard)

            def micro_step(carry, mb):
                grads_acc, loss_acc = carry
                (lv, mets), g = jax.value_and_grad(
                    self.loss, has_aux=True)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), grads_acc, g)
                return (grads_acc, loss_acc + lv), mets

            # accumulate in f32 (also avoids XLA CPU's bf16 all-reduce
            # promotion crash when the deferred psum runs)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), mets = jax.lax.scan(
                micro_step, (zeros, jnp.zeros((), jnp.float32)), micro)
            # THE deferred reduction: one psum after the accumulation
            grads = jax.lax.psum(grads, dp)
            loss_sum = jax.lax.psum(loss_sum, dp)
            mets = jax.lax.psum(mets, dp)
            return grads, loss_sum, mets

        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        batch_spec = jax.tree.map(lambda _: P(dp), batch)
        grads, loss_sum, mets = _shard_map(
            per_shard, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params), batch_spec),
            out_specs=(jax.tree.map(lambda _: P(), params), P(), P()),
            check_rep=False, axis_names=dp)(params, batch)
        grads = jax.tree.map(lambda g: g / (accum * n_dp), grads)
        loss_val = loss_sum / (accum * n_dp)
        metrics = jax.tree.map(lambda m: m.mean() / n_dp, mets)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda w, u: w + u.astype(w.dtype),
                              params, updates)
        metrics = dict(metrics, loss=loss_val)
        return params, opt_state, metrics

    # ------------------------------------------------------- prefill / decode

    def prefill(self, params: Params, batch: dict, max_len: int, *,
                rolling: bool = False) -> tuple[Array, Params]:
        """Forward over the prompt; returns (last-token logits, caches).

        The caches are *filled by re-running decode semantics* only in the
        serve path; for the assigned prefill shape we need the forward pass
        itself (logits + final hidden), which is what gets lowered.
        """
        logits, _, _ = self.forward(params, batch)
        caches = self.init_cache(batch["tokens"].shape[0], max_len,
                                 rolling=rolling)
        return logits[:, -1:], caches

    def init_cache(self, batch: int, max_len: int, *,
                   rolling: bool = False) -> Params:
        memory_len = AUDIO_MEMORY if self.cfg.is_encoder_decoder else 0
        return transformer.init_stack_cache(self.cfg, batch, max_len,
                                            rolling, memory_len)

    def decode_step(self, params: Params, caches: Params, tokens: Array,
                    *, rolling: bool = False) -> tuple[Array, Params]:
        """ONE new token (B, 1) against the caches."""
        cfg = self.cfg
        x = layers.embed(params["embedding"], tokens)
        x, caches = transformer.decode_stack(cfg, params["stack"], caches, x,
                                             rolling=rolling)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        logits = layers.unembed(cfg, params["embedding"], x)
        return logits, caches

    # ------------------------------------------------------------ input specs

    def input_specs(self, shape: InputShape) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = layers.dtype_of(cfg)
        sds = jax.ShapeDtypeStruct
        if cfg.is_encoder_decoder:
            if shape.step == "train":
                dec = s // DEC_FRACTION
                return {"frames": sds((b, s, cfg.d_model), dt),
                        "tokens": sds((b, dec), i32),
                        "targets": sds((b, dec), i32)}
            if shape.step == "prefill":
                return {"frames": sds((b, s, cfg.d_model), dt),
                        "tokens": sds((b, 1), i32),
                        "targets": sds((b, 1), i32)}
            return {"tokens": sds((b, 1), i32)}     # decode
        if cfg.arch_type == "vlm" and shape.step != "decode":
            npfx = cfg.frontend.num_embeddings
            text = s - npfx
            return {"tokens": sds((b, text), i32),
                    "targets": sds((b, text), i32),
                    "vision_embeds": sds((b, npfx, cfg.d_model), dt)}
        if shape.step == "decode":
            return {"tokens": sds((b, 1), i32)}
        return {"tokens": sds((b, s), i32),
                "targets": sds((b, s), i32)}

    def cache_specs(self, shape: InputShape, *, rolling: bool = False):
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len,
                                    rolling=rolling))


def _next_token_ce(logits: Array, targets: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    return Model(cfg)
