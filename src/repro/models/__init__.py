"""Model substrate: layers, attention variants, MoE, SSM, RG-LRU, stacks."""
