"""Attention variants: MHA/GQA/MQA (+bias, RoPE), MLA, sliding window, caches.

Long-sequence forward passes use a *block-causal chunked* computation: an
unrolled loop over query chunks where chunk i only contracts against keys
[lo_i, hi_i) with **static** slice bounds — so the lowered HLO performs the
causally-required FLOPs only (no full S² score buffer materializes; memory is
O(chunk × window)). This is the portable jnp path; `repro.kernels.
flash_attention` is the TPU Pallas version with the same blocking.

Caches:
  full cache    {'k','v': (B, S_max, Hkv, hd), 'pos': ()}       decode_32k
  rolling cache {'k','v': (B, W, Hkv, hd), 'slot_pos': (W,), 'pos': ()}
                (sliding-window / long_500k)
  MLA cache     {'c_kv': (B, S, r), 'k_rope': (B, S, 1, hd_r), 'pos': ()}
                (compressed latent — the point of MLA)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params, apply_rope, dense_init, dtype_of

Array = jax.Array

NEG_INF = -2.0 ** 30  # large-negative in f32 (avoids bf16 overflow on cast)
CHUNK = 2048          # query/key chunk for block-causal attention


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg)
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 6)
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "q_down": dense_init(ks[0], (cfg.d_model, m.q_lora_rank), dt),
            "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dt)},
            "q_up": dense_init(ks[1], (m.q_lora_rank,
                                       cfg.num_heads * qk_hd), dt),
            "kv_down": dense_init(ks[2], (cfg.d_model,
                                          m.kv_lora_rank + m.qk_rope_head_dim),
                                  dt),
            "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dt)},
            "kv_up": dense_init(ks[3], (m.kv_lora_rank, cfg.num_heads *
                                        (m.qk_nope_head_dim + m.v_head_dim)),
                                dt),
            "o": dense_init(ks[4], (cfg.num_heads * m.v_head_dim,
                                    cfg.d_model), dt),
        }
    ks = jax.random.split(key, 4)
    p = {
        "q": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), dt),
        "k": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), dt),
        "v": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), dt),
        "o": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["q_b"] = jnp.zeros((cfg.num_heads * hd,), dt)
        p["k_b"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
        p["v_b"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
    return p


def init_cross_attention(cfg: ModelConfig, key) -> Params:
    return init_attention(cfg, key)   # same projections, keys from memory


# ---------------------------------------------------------------------------
# core score/combine (single q-block vs single kv-block)
# ---------------------------------------------------------------------------

def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """q/k: (B,S,*,qk_hd); v: (B,Sk,Hkv,v_hd); mask bcastable (B,1,Sq,Sk).

    v_hd may differ from qk_hd (MLA decompresses to different dims)."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])


def block_causal_attention(q: Array, k: Array, v: Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           chunk: int = CHUNK) -> Array:
    """Chunked attention with static per-chunk key slices (causal FLOPs only).

    q/k/v over the same sequence; q: (B,S,H,hd), k/v: (B,S,Hkv,hd).
    """
    b, s, h, hd = q.shape
    if s <= chunk:
        mask = None
        if causal:
            qpos = jnp.arange(s)
            mask = qpos[:, None] >= qpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - qpos[None, :] < window
            mask = mask[None, None]
        return _sdpa(q, k, v, mask)

    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    outs = []
    for i in range(n_chunks):
        q_lo, q_hi = i * chunk, (i + 1) * chunk
        k_lo = 0 if window is None else max(0, q_lo - window)
        k_lo = (k_lo // chunk) * chunk           # align to chunk
        k_hi = q_hi if causal else s
        qi = q[:, q_lo:q_hi]
        ki = k[:, k_lo:k_hi]
        vi = v[:, k_lo:k_hi]
        qpos = jnp.arange(q_lo, q_hi)
        kpos = jnp.arange(k_lo, k_hi)
        mask = jnp.ones((chunk, k_hi - k_lo), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        outs.append(_sdpa(qi, ki, vi, mask[None, None]))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# GQA attention (train/prefill + cached decode)
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: Params, x: Array):
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ p["q"]
    k = x @ p["k"]
    v = x @ p["v"]
    if cfg.qkv_bias:
        q, k, v = q + p["q_b"], k + p["k_b"], v + p["v_b"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def gqa_forward(cfg: ModelConfig, p: Params, x: Array, *,
                positions: Optional[Array] = None,
                causal: bool = True,
                window: Optional[int] = None) -> Array:
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    from repro.sharding import hints
    q, k, v = hints.hint_qkv(q, k, v)
    out = block_causal_attention(q, k, v, causal=causal, window=window)
    return out.reshape(b, s, -1) @ p["o"]


def gqa_cross_forward(cfg: ModelConfig, p: Params, x: Array,
                      memory: Array) -> Array:
    """Cross-attention: queries from x, keys/values from encoder memory."""
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    sm = memory.shape[1]
    q = (x @ p["q"]).reshape(b, s, cfg.num_heads, hd)
    k = (memory @ p["k"]).reshape(b, sm, cfg.num_kv_heads, hd)
    v = (memory @ p["v"]).reshape(b, sm, cfg.num_kv_heads, hd)
    if cfg.qkv_bias:
        q = q + p["q_b"].reshape(cfg.num_heads, hd)
        k = k + p["k_b"].reshape(cfg.num_kv_heads, hd)
        v = v + p["v_b"].reshape(cfg.num_kv_heads, hd)
    out = _sdpa(q, k, v, None)
    return out.reshape(b, s, -1) @ p["o"]


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int,
                   rolling: bool = False) -> Params:
    hd = cfg.resolved_head_dim
    dt = dtype_of(cfg)
    size = min(max_len, cfg.sliding_window) if rolling and cfg.sliding_window \
        else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
        "slot_pos": jnp.full((size,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def gqa_decode_step(cfg: ModelConfig, p: Params, cache: Params,
                    x_t: Array, rolling: bool = False) -> tuple[Array, Params]:
    """One token: x_t (B, 1, D) against the cache."""
    b = x_t.shape[0]
    pos = cache["pos"]
    q, k, v = _project_qkv(cfg, p, x_t)
    pos_arr = pos[None, None]
    q = apply_rope(q, jnp.broadcast_to(pos_arr, (b, 1)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(pos_arr, (b, 1)), cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = (pos % size) if rolling else jnp.minimum(pos, size - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], pos[None], (slot,))

    window = cfg.sliding_window
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= slot_pos > pos - window
    mask = valid[None, None, None, :]                    # (1,1,1,size)
    out = _sdpa(q, ck, cv, mask)
    out = out.reshape(b, 1, -1) @ p["o"]
    new_cache = {"k": ck, "v": cv, "slot_pos": slot_pos, "pos": pos + 1}
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3) — compressed-latent cache; absorbed decode
# ---------------------------------------------------------------------------

def _mla_qkv(cfg: ModelConfig, p: Params, x: Array, positions: Array):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    cq = layers.apply_norm(cfg, p["q_norm"], x @ p["q_down"])
    q = (cq @ p["q_up"]).reshape(b, s, h, m.qk_nope_head_dim
                                 + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["kv_down"]
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = layers.apply_norm(cfg, p["kv_norm"], c_kv)       # (B,S,r)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)                      # (B,S,1,hd_r)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(cfg: ModelConfig, p: Params, x: Array, *,
                positions: Optional[Array] = None,
                window: Optional[int] = None) -> Array:
    """Full-sequence MLA (train / prefill): decompress k/v, standard SDPA."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)
    kv = (c_kv @ p["kv_up"]).reshape(b, s, h,
                                     m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))],
        axis=-1)
    from repro.sharding import hints
    q, k, v = hints.hint_qkv(q, k, v)
    out = block_causal_attention(q, k, v, causal=True, window=window)
    out = out.reshape(b, s, h * m.v_head_dim)
    return out @ p["o"]


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    m = cfg.mla
    dt = dtype_of(cfg)
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, 1, m.qk_rope_head_dim), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_decode_step(cfg: ModelConfig, p: Params, cache: Params,
                    x_t: Array) -> tuple[Array, Params]:
    """Absorbed MLA decode: scores in latent space — O(S·r) per head group,
    the compressed cache never decompresses to per-head K/V."""
    m = cfg.mla
    b = x_t.shape[0]
    h = cfg.num_heads
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_qkv(cfg, p, x_t, positions)

    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_t, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_t,
                                          (0, pos, 0, 0))
    s_max = c_kv.shape[1]

    # absorb W_uk into q: q_lat (B,1,H,r).  kv_up columns are laid out
    # per-head interleaved [k_nope | v] (matching mla_forward's reshape)
    w_full = p["kv_up"].reshape(m.kv_lora_rank, h,
                                m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_full[:, :, :m.qk_nope_head_dim]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scores = jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv,
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bqhd,bkzd->bhqk", q_rope, k_rope,
                         preferred_element_type=jnp.float32)
    scores *= 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    valid = jnp.arange(s_max) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    # combine in latent space, then decompress through W_uv
    lat = jnp.einsum("bhqk,bkr->bqhr", probs.astype(c_kv.dtype), c_kv)
    w_uv = w_full[:, :, m.qk_nope_head_dim:]
    out = jnp.einsum("bqhr,rhd->bqhd", lat, w_uv)
    out = out.reshape(b, 1, h * m.v_head_dim) @ p["o"]
    new_cache = {"c_kv": c_kv, "k_rope": k_rope, "pos": pos + 1}
    return out, new_cache
