"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within a chunk the recurrence is computed as dense
(MXU-friendly) matmuls with a decay-weighted score matrix; states are carried
across chunks with a scan — exactly the structure the paper derives as the
"dual" form.  ``repro.kernels.ssd_scan`` is the Pallas/TPU version of the
chunk kernel; this file is the portable jnp implementation (and its oracle).

Block layout (simplified Mamba-2):
  in_proj  : D -> [z (d_in), x (d_in), B (G·N), C (G·N), dt (H)]
  conv1d   : causal depthwise over [x, B, C]
  SSD      : h_t = exp(dt·A) h_{t-1} + dt·B_t ⊗ x_t ;  y_t = C_t · h_t
  out      : y · silu(z)  -> out_proj
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params, dense_init, dtype_of

Array = jax.Array


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads, s.n_groups, s.d_state


def init_ssm(cfg: ModelConfig, key) -> Params:
    s = cfg.ssm
    dt = dtype_of(cfg)
    d_in, h, g, n = dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * g * n + h
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, proj_out), dt),
        "conv": layers.init_conv(cfg, ks[1], d_in + 2 * g * n, s.conv_kernel),
        "a_log": jnp.zeros((h,), jnp.float32),     # A = -exp(a_log) ∈ (-∞,0)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, cfg.d_model), dt),
    }


def _split_proj(cfg: ModelConfig, proj: Array):
    d_in, h, g, n = dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_in + 2 * g * n], axis=-1)
    return z, xbc, dt_raw


def _split_xbc(cfg: ModelConfig, xbc: Array):
    d_in, h, g, n = dims(cfg)
    x, bc = jnp.split(xbc, [d_in], axis=-1)
    b_mat, c_mat = jnp.split(bc, [g * n], axis=-1)
    return x, b_mat, c_mat


def ssd_chunked(x: Array, dt: Array, a: Array, b_mat: Array, c_mat: Array,
                chunk: int, h0: Optional[Array] = None
                ) -> tuple[Array, Array]:
    """Chunked SSD scan.

    x:     (B, S, H, P)   per-head inputs
    dt:    (B, S, H)      softplus-ed timestep
    a:     (H,)           negative decay rate (A = -exp(a_log))
    b_mat: (B, S, G, N)   input projections  (G groups broadcast over H)
    c_mat: (B, S, G, N)   output projections
    h0:    (B, H, P, N)   initial state (decode/resume)
    returns (y (B,S,H,P), h_final (B,H,P,N))
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    da = dtc * a                                   # (B,NC,L,H) log-decay
    cum = jnp.cumsum(da, axis=2)                   # within-chunk cumulative

    # intra-chunk (dual / attention-like) term:
    #   scores[t, u] = C_t · B_u · exp(cum_t − cum_u) · dt_u,  u ≤ t
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    scores = jnp.einsum("bclhn,bcuhn->bcluh", cc, bc) * decay  # (B,NC,L,U,H)
    scores = scores * dtc[:, :, None, :, :]        # weight by dt_u
    y_intra = jnp.einsum("bcluh,bcuhp->bclhp", scores, xc)

    # chunk-final states: h_c = Σ_u exp(cum_L − cum_u)·dt_u · B_u ⊗ x_u
    w_state = jnp.exp(cum[:, :, -1:, :] - cum) * dtc    # (B,NC,L,H)
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn", w_state, bc, xc,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence over chunk-level decays (f32 carry)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,NC,H)

    def scan_fn(h_prev, inp):
        st, dec = inp                                   # (B,H,P,N), (B,H)
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev                            # emit state BEFORE

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)
    states_t = states.transpose(1, 0, 2, 3, 4)          # (NC,B,H,P,N)
    decay_t = chunk_decay.transpose(1, 0, 2)            # (NC,B,H)
    h_final, h_prevs = jax.lax.scan(scan_fn, h0, (states_t, decay_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (B,NC,H,P,N)

    # contribution of the carried-in state to each position
    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp", cc, h_prevs,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, h_final


def ssm_forward(cfg: ModelConfig, p: Params, xin: Array,
                use_kernel: bool = False) -> Array:
    """Full-sequence mixer forward: (B, S, D) -> (B, S, D)."""
    s_cfg = cfg.ssm
    d_in, h, g, n = dims(cfg)
    bsz, s, _ = xin.shape
    proj = xin @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = layers.apply_conv(p["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    x, b_mat, c_mat = _split_xbc(cfg, xbc)

    x = x.reshape(bsz, s, h, s_cfg.head_dim)
    b_mat = b_mat.reshape(bsz, s, g, n)
    c_mat = c_mat.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if use_kernel:
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(x, dt, a, b_mat, c_mat, chunk=s_cfg.chunk_size)
    else:
        chunk = min(s_cfg.chunk_size, s)
        y, _ = ssd_chunked(x, dt, a, b_mat, c_mat, chunk)
    y = y + x * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, s, d_in).astype(xin.dtype) * jax.nn.silu(z)
    return (y @ p["out_proj"]).astype(xin.dtype)


# ---------------------------------------------------------------------------
# decode: single-token recurrence against carried (conv, ssm) state
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int) -> Params:
    s = cfg.ssm
    d_in, h, g, n = dims(cfg)
    dt = dtype_of(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, d_in + 2 * g * n), dt),
        "h": jnp.zeros((batch, h, s.head_dim, n), dt),
    }


def ssm_decode_step(cfg: ModelConfig, p: Params, cache: Params,
                    x_t: Array) -> tuple[Array, Params]:
    """x_t: (B, 1, D) -> (B, 1, D); O(1) state update (the SSM advantage)."""
    s_cfg = cfg.ssm
    d_in, h, g, n = dims(cfg)
    bsz = x_t.shape[0]
    proj = x_t[:, 0, :] @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = layers.apply_conv_step(p["conv"], cache["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    x, b_mat, c_mat = _split_xbc(cfg, xbc)

    x = x.reshape(bsz, h, s_cfg.head_dim)
    b_mat = jnp.repeat(b_mat.reshape(bsz, g, n), h // g, axis=1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, g, n), h // g, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    decay = jnp.exp(dt * -jnp.exp(p["a_log"]))          # (B, H)

    h_new = cache["h"] * decay[:, :, None, None].astype(x.dtype) + \
        jnp.einsum("bhp,bhn,bh->bhpn", x, b_mat, dt.astype(x.dtype))
    y = jnp.einsum("bhn,bhpn->bhp", c_mat, h_new)
    y = y + x * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, d_in) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": conv_state, "h": h_new}
