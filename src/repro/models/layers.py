"""Shared layer primitives: norms, gated/ungated MLPs, RoPE, embeddings.

Functional style: ``init_*`` builds a param dict, ``apply_*`` consumes it.
Params live in the config dtype (bf16 for the big archs); norm statistics,
softmax and rotary math run in f32.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array
Params = dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: float | None = None) -> Array:
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int) -> Params:
    p = {"scale": jnp.ones((dim,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype_of(cfg))
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: Array) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants (swiglu / geglu gated; relu2 = squared ReLU (Nemotron); gelu)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_model: int, d_ff: int) -> Params:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[2], (d_ff, d_model), dt)}
    if cfg.mlp in ("swiglu", "geglu"):
        p["gate"] = dense_init(ks[0], (d_model, d_ff), dt)
        p["up"] = dense_init(ks[1], (d_model, d_ff), dt)
    else:
        p["up"] = dense_init(ks[1], (d_model, d_ff), dt)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: Array) -> Array:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["gate"], approximate=True) * (x @ p["up"])
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["up"]))
    else:
        h = jax.nn.gelu(x @ p["up"], approximate=True)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]             # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"table": dense_init(k1, (cfg.vocab_size, cfg.d_model), dt, scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed(p: Params, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(cfg: ModelConfig, p: Params, x: Array) -> Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["table"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"],
                            preferred_element_type=jnp.float32)
    return logits


# ---------------------------------------------------------------------------
# depthwise causal conv (mamba2 / RG-LRU blocks) with streaming state
# ---------------------------------------------------------------------------

def init_conv(cfg: ModelConfig, key, width: int, kernel: int) -> Params:
    dt = dtype_of(cfg)
    return {"w": dense_init(key, (kernel, width), dt, scale=0.5),
            "b": jnp.zeros((width,), dt)}


def apply_conv(p: Params, x: Array) -> Array:
    """Causal depthwise conv over (B, S, W)."""
    k = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["w"][i] for i in range(k))
    return out + p["b"]


def apply_conv_step(p: Params, state: Array, x_t: Array):
    """One decode step. state: (B, k-1, W) past inputs; x_t: (B, W)."""
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, k, W)
    out = jnp.einsum("bkw,kw->bw", window, p["w"]) + p["b"]
    return out, window[:, 1:, :]
