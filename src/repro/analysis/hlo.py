"""Optimized-HLO text parsing and the trip-count-aware census.

This is the parsing substrate every HLO-level lint rule and the roofline
share (it moved here from ``launch/roofline.py``, which re-exports the
public names for its callers).  ``compiled.cost_analysis()`` counts every
HLO op ONCE — loop bodies (lax.scan over layers, grad-accumulation
microbatches, backtracking line searches) are not multiplied by their trip
counts, so its FLOPs understate a scanned stack by ~L×.  This module
instead walks the optimized HLO text:

  * computations are parsed into instruction lists (``parse_hlo``);
  * ``while`` ops multiply their body's costs by the trip count recovered
    from the loop condition (canonical `i < C` compare against a constant);
  * ``fusion`` / ``call`` / ``conditional`` recurse with multiplier 1;
  * FLOPs: 2·prod(result_dims)·K for every dot (K = contracted lhs dims),
    plus convolution terms;
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (trip-weighted);
  * HBM byte proxy: operand+result sizes at fusion granularity (fusion
    internals live in registers/VMEM), trip-weighted.

Beyond the census, the analysis rules (``repro.analysis.rules``) consume
the raw ``Instr`` stream via ``iter_instructions`` — severities, rule ids
and waivers live there, this module stays a pure parser.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Iterator, Optional

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "u4": 1, "s4": 1,
}

# skip these when accumulating the HBM-traffic proxy
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", "broadcast", "while", "conditional", "call",
               "custom-call", "copy-start", "copy-done"}

# ops that touch only a slice of their big operand (in-place / sparse):
# counting the full operand would blow up trip-weighted loops (a DUS into a
# stacked (L, ...) buffer reads the slice, not the whole buffer)
_SLICE_TRAFFIC = {"dynamic-update-slice", "dynamic-slice", "gather",
                  "scatter", "slice", "pad", "concatenate"}


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_dims: tuple[int, ...]
    dtype: str
    operands: list[str]
    attrs: str
    tuple_bytes: int = 0       # for tuple-typed results


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]


# computation definitions start at column 0: "%name (args...) -> type {"
# (args may contain nested parens — match only the name and trailing '{')
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_SHAPED = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPNAME = re.compile(r"([a-z][\w\-]*)\(")


def _parse_shape_bytes(type_str: str) -> tuple[int, tuple[int, ...], str]:
    m = _SHAPED.match(type_str.strip())
    if not m:
        return 0, (), ""
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0, (), ""
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    n = 1
    for d in shape:
        n *= d
    return n * _DTYPE_BYTES[dtype], shape, dtype


def _operand_names(body: str, opname: str) -> list[str]:
    """Operand instruction names from 'op(...)' (first balanced parens)."""
    idx = body.find(opname + "(")
    if idx < 0:
        return []
    tail = body[idx + len(opname) + 1:]
    depth, args = 1, ""
    for ch in tail:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    names = []
    for a in args.split(","):
        # operands are written "f32[16,16]{1,0} %name" — the name follows
        # the (optional) type annotation, so search, don't anchor
        m = re.search(r"%([\w.\-]+)", a)
        if m:
            names.append(m.group(1))
    return names


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            if line and not line[0].isspace():
                m = _COMP_START.match(line)
                if m:
                    current = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, body = m.groups()
        # result type: up to the op name
        if body.startswith("("):
            # tuple type: find matching ')' then op
            depth, i = 0, 0
            for i, ch in enumerate(body):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            tuple_type, rest = body[:i + 1], body[i + 1:]
            tbytes = sum(_parse_shape_bytes(f"{d}[{s}]")[0]
                         for d, s in _SHAPED.findall(tuple_type))
            rbytes, rdims, dtype = 0, (), ""
        else:
            parts = body.split(None, 1)
            rbytes, rdims, dtype = _parse_shape_bytes(parts[0])
            rest = parts[1] if len(parts) > 1 else ""
            tbytes = 0
        om = _OPNAME.search(rest)
        op = om.group(1) if om else ""
        operands = _operand_names(rest, op) if op else []
        current.instrs.append(Instr(name, op, rbytes, rdims, dtype,
                                    operands, rest, tbytes))
    return comps


def iter_instructions(comps: dict[str, Computation]
                      ) -> Iterator[tuple[Computation, Instr]]:
    """Every instruction of every computation, with its computation."""
    for comp in comps.values():
        for ins in comp.instrs:
            yield comp, ins


def entry_computation(text: str, comps: dict[str, Computation]) -> str:
    """Name of the ENTRY computation (fallback: the largest one)."""
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                return m.group(1)
            break
    return max(comps, key=lambda k: len(comps[k].instrs))


_PAIRS = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_PAIR = re.compile(r"\{(\d+),(\d+)\}")


def permute_pairs(ins: Instr) -> frozenset[tuple[int, int]]:
    """The ``source_target_pairs`` of a collective-permute instruction."""
    m = _PAIRS.search(ins.attrs)
    if not m:
        return frozenset()
    return frozenset((int(a), int(b)) for a, b in _PAIR.findall(m.group(1)))


def base_op(ins: Instr) -> str:
    """Async collectives split into -start/-done; fold onto the base op."""
    for suffix in ("-start", "-done"):
        if ins.op.endswith(suffix):
            return ins.op[:-len(suffix)]
    return ins.op


def _trip_count(cond: Computation) -> int:
    """Canonical scan condition: compare(i, C) direction=LT with C constant
    (possibly via a wrapped fusion). Fallback: any s32 scalar constant."""
    consts: dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant" and ins.dtype in ("s32", "u32", "s64"):
            m = re.search(r"constant\((\d+)\)", ins.attrs)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if "direction=LT" in ins.attrs or ins.op == "compare" \
                or "compare" in ins.attrs:
            for o in ins.operands:
                if o in consts:
                    return consts[o]
    if consts:
        return max(consts.values())
    return 1


_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")


def _dot_flops(ins: Instr, sizes: dict[str, tuple[int, ...]]) -> float:
    """2 · prod(result) · K, K = product of lhs contracting dims."""
    res = 1
    for d in ins.result_dims:
        res *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    k = 1
    if m and ins.operands:
        lhs_shape = sizes.get(ins.operands[0], ())
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_shape):
                k *= lhs_shape[int(idx)]
    return 2.0 * res * k


@dataclasses.dataclass
class Census:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {op: {"count": 0, "bytes": 0.0}
                                 for op in COLLECTIVE_OPS})
    while_trips: list = dataclasses.field(default_factory=list)

    def scaled_add(self, other: "Census", mult: float) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for op in COLLECTIVE_OPS:
            self.collectives[op]["count"] += other.collectives[op]["count"] * mult
            self.collectives[op]["bytes"] += other.collectives[op]["bytes"] * mult
        self.while_trips.extend(other.while_trips)


def hlo_census(text: str) -> Census:
    comps = parse_hlo(text)
    # result shapes per instruction name (for dot K lookup), global
    shapes: dict[str, tuple[int, ...]] = {}
    bytes_of: dict[str, int] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = ins.result_dims
            bytes_of[ins.name] = ins.result_bytes or ins.tuple_bytes

    memo: dict[str, Census] = {}

    def walk(name: str) -> Census:
        if name in memo:
            return memo[name]
        memo[name] = Census()          # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = Census()
        for ins in comp.instrs:
            if ins.op == "dot":
                c.flops += _dot_flops(ins, shapes)
            elif ins.op == "convolution":
                # 2 · result_size · (kernel elements / out_channels)
                res = 1
                for d in ins.result_dims:
                    res *= d
                kern = 1
                if len(ins.operands) > 1:
                    for d in shapes.get(ins.operands[1], ()):
                        kern *= d
                out_ch = ins.result_dims[-1] if ins.result_dims else 1
                c.flops += 2.0 * res * max(kern, 1) / max(out_ch, 1)
            bop = base_op(ins) if ins.op.endswith("-start") else ins.op
            if bop in COLLECTIVE_OPS:
                nbytes = sum(bytes_of.get(o, 0) for o in ins.operands)
                if bop == "all-gather":
                    # per-device wire volume: the (n_shards-1)/n_shards of
                    # the gathered result received from peers.  The operand
                    # alone (this shard's contribution) understates a ring
                    # all-gather by n_shards×, which would make it look
                    # cheaper than a neighbour-only permute schedule that
                    # moves strictly fewer rows.  An async all-gather-start
                    # carries its input buffer inside the result tuple —
                    # drop it before subtracting the own contribution.
                    total = ins.result_bytes
                    if not total and ins.tuple_bytes:
                        total = ins.tuple_bytes - nbytes
                    nbytes = max(total - nbytes, nbytes)
                c.collective_bytes += nbytes
                c.collectives[bop]["count"] += 1
                c.collectives[bop]["bytes"] += nbytes
            # HBM traffic proxy at fusion granularity
            if ins.op and ins.op not in _NO_TRAFFIC:
                out_b = ins.result_bytes or ins.tuple_bytes
                if ins.op in _SLICE_TRAFFIC:
                    if ins.op == "dynamic-update-slice" and \
                            len(ins.operands) > 1:
                        upd = bytes_of.get(ins.operands[1], 0)
                        c.hbm_bytes += 2 * upd
                    else:
                        c.hbm_bytes += 2 * out_b
                else:
                    in_b = sum(bytes_of.get(o, 0) for o in ins.operands)
                    c.hbm_bytes += out_b + in_b
            # recurse
            if ins.op == "while":
                bm, cm = _BODY.search(ins.attrs), _COND.search(ins.attrs)
                trip = _trip_count(comps[cm.group(1)]) if cm and \
                    cm.group(1) in comps else 1
                c.while_trips.append(trip)
                if bm and bm.group(1) in comps:
                    c.scaled_add(walk(bm.group(1)), trip)
            else:
                cm = _CALLS.search(ins.attrs)
                if cm and cm.group(1) in comps:
                    sub = walk(cm.group(1))
                    # fusion internals are not HBM traffic; flops/colls are
                    sub2 = Census(flops=sub.flops,
                                  collective_bytes=sub.collective_bytes,
                                  collectives=sub.collectives,
                                  while_trips=sub.while_trips)
                    c.scaled_add(sub2, 1.0)
        memo[name] = c
        return c

    return walk(entry_computation(text, comps))


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Trip-count-aware collective census (kept as the dryrun JSON field)."""
    c = hlo_census(hlo_text)
    out: dict[str, Any] = {
        op: {"count": c.collectives[op]["count"],
             "bytes": c.collectives[op]["bytes"]}
        for op in COLLECTIVE_OPS}
    out["total_bytes"] = c.collective_bytes
    return out
