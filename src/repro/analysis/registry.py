"""Rule registry and the analysis context rules run against.

A rule is a function ``(AnalysisContext) -> Iterable[Finding]`` registered
under a stable id (``family/name``).  Rules must *skip* (yield nothing)
when the context lacks what they inspect — an HLO rule on a jaxpr-only
context is vacuous, not an error — so one registry serves every entry
point (trainer analysis, canned-HLO unit tests, kernel-spec lints).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, Optional, Sequence

from repro.analysis import hlo as hlo_mod
from repro.analysis.findings import (Finding, Report, Severity, Waiver,
                                     apply_waivers)

RuleFn = Callable[["AnalysisContext"], Iterable[Finding]]


@dataclasses.dataclass
class AnalysisContext:
    """What a rule may inspect.  Any field may be None/empty; rules skip
    what is absent.

    expectations — facts about the config under analysis that rules
    check the program against.  Keys used by the built-in rules:

      transport                "p2p" | "allgather"
      round_pairs              list of per-round frozensets of (src, dst)
      num_gathers              host-side gathers per trainer step
      collective_budget_bytes  bound on transport payload bytes (census)
      allreduce_max_bytes      bound on any single all-reduce operand
      m_total, lanes, n_pad, max_deg   layout facts for the dense-adjacency
                               pattern matcher
      dense_adjacency_allowed  True on the dense baseline config
      hbm_intermediate_budget  bound on any single intermediate's bytes
      args_donated             {arg_path: bool} from lowered.args_info
      expect_donated           substrings of arg paths that must be donated
      allow_f64                True to mute the f64-leak rule
      kernels                  list of kernel-spec dicts for Pallas rules
    """
    hlo_text: Optional[str] = None
    jaxpr: Any = None                  # jax.core.ClosedJaxpr or None
    expectations: dict[str, Any] = dataclasses.field(default_factory=dict)
    config: str = ""
    _comps: Optional[dict[str, hlo_mod.Computation]] = \
        dataclasses.field(default=None, repr=False)

    @property
    def computations(self) -> dict[str, hlo_mod.Computation]:
        if self._comps is None:
            self._comps = hlo_mod.parse_hlo(self.hlo_text or "")
        return self._comps

    def instructions(self):
        return hlo_mod.iter_instructions(self.computations)

    def census(self) -> hlo_mod.Census:
        return hlo_mod.hlo_census(self.hlo_text or "")


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    fn: RuleFn
    severity: Severity                 # default severity, shown in catalogue
    doc: str

    @property
    def family(self) -> str:
        return self.id.split("/", 1)[0]


_REGISTRY: dict[str, Rule] = {}


def rule(id: str, *, severity: Severity = Severity.ERROR
         ) -> Callable[[RuleFn], RuleFn]:
    """Register a rule under ``id`` (``family/name``)."""
    def deco(fn: RuleFn) -> RuleFn:
        doc = (fn.__doc__ or "").strip().splitlines()
        _REGISTRY[id] = Rule(id, fn, severity, doc[0] if doc else "")
        return fn
    return deco


def get_rule(id: str) -> Rule:
    _ensure_builtin_rules()
    return _REGISTRY[id]


def all_rules(family: Optional[str] = None) -> list[Rule]:
    _ensure_builtin_rules()
    rules = sorted(_REGISTRY.values(), key=lambda r: r.id)
    if family is not None:
        rules = [r for r in rules if r.family == family]
    return rules


def _ensure_builtin_rules() -> None:
    # rule modules self-register on import; idempotent
    from repro.analysis.rules import (collective, memory,  # noqa: F401
                                      pallas, precision)


def run_rules(ctx: AnalysisContext,
              rules: Optional[Sequence[str]] = None,
              waivers: Sequence[Waiver] = (),
              families: Optional[Sequence[str]] = None) -> Report:
    """Run (a subset of) the registry against ``ctx`` and build a Report."""
    _ensure_builtin_rules()
    if rules is not None:
        picked = [get_rule(r) for r in rules]
    else:
        picked = all_rules()
        if families is not None:
            fams = set(families)
            picked = [r for r in picked if r.family in fams]
    found: list[Finding] = []
    for r in picked:
        found.extend(r.fn(ctx))
    kept, muted = apply_waivers(found, ctx.expectations, waivers)
    return Report(config=ctx.config,
                  expectations=dict(ctx.expectations),
                  findings=kept, waived=muted,
                  rules_run=[r.id for r in picked])


def analyze_hlo(hlo_text: str,
                expectations: Optional[Mapping[str, Any]] = None,
                *, config: str = "",
                rules: Optional[Sequence[str]] = None,
                waivers: Sequence[Waiver] = ()) -> Report:
    """Lint a compiled-HLO dump against ``expectations``."""
    ctx = AnalysisContext(hlo_text=hlo_text,
                          expectations=dict(expectations or {}),
                          config=config)
    return run_rules(ctx, rules=rules, waivers=waivers)
