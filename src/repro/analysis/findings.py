"""Findings, severities, reports, and per-config waivers.

A *finding* is one violated invariant at one location; a *report* is the
outcome of running a rule set over one analysis context (one compiled
config).  Waivers mute a rule for configs that legitimately trip it —
e.g. the dense-adjacency rule on the dense baseline trainer — while
keeping the finding visible in the report's ``waived`` list.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Any, Iterable, Mapping, Optional, Sequence


class Severity(enum.IntEnum):
    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:   # "error", not "Severity.ERROR", in reports
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant at one location."""
    rule: str                          # rule id, e.g. "collective/no-allgather"
    severity: Severity
    message: str
    location: str = ""                 # instruction/computation/kernel name
    details: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        return {"rule": self.rule, "severity": str(self.severity),
                "message": self.message, "location": self.location,
                "details": dict(self.details)}

    def __str__(self) -> str:
        loc = f" @ {self.location}" if self.location else ""
        return f"[{self.severity}] {self.rule}{loc}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Waiver:
    """Mute ``rule`` on configs whose expectations match ``when``.

    ``when`` maps expectation keys to required values; an empty mapping
    waives the rule unconditionally.  Waived findings stay in the report
    (``report.waived``) so the JSON artifact still shows what was muted.
    """
    rule: str
    reason: str
    when: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def matches(self, finding: Finding,
                expectations: Mapping[str, Any]) -> bool:
        if finding.rule != self.rule:
            return False
        return all(expectations.get(k) == v for k, v in self.when.items())


@dataclasses.dataclass
class Report:
    """Findings from one rule run over one config."""
    config: str = ""
    expectations: dict[str, Any] = dataclasses.field(default_factory=dict)
    findings: list[Finding] = dataclasses.field(default_factory=list)
    waived: list[Finding] = dataclasses.field(default_factory=list)
    rules_run: list[str] = dataclasses.field(default_factory=list)

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity >= Severity.ERROR]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == Severity.WARNING]

    def findings_for(self, rule: str) -> list[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def no_findings(self, rule: Optional[str] = None,
                    min_severity: Severity = Severity.WARNING) -> bool:
        """True iff no finding at/above ``min_severity`` (for ``rule``)."""
        for f in self.findings:
            if rule is not None and f.rule != rule:
                continue
            if f.severity >= min_severity:
                return False
        return True

    def assert_no_findings(self, rule: Optional[str] = None,
                           min_severity: Severity = Severity.WARNING) -> None:
        if not self.no_findings(rule, min_severity):
            raise AssertionError(self.summary(rule))

    def summary(self, rule: Optional[str] = None) -> str:
        picked = [f for f in self.findings
                  if rule is None or f.rule == rule]
        head = (f"{self.config or 'analysis'}: "
                f"{len(self.errors())} error(s), "
                f"{len(self.warnings())} warning(s), "
                f"{len(self.waived)} waived, "
                f"{len(self.rules_run)} rule(s) run")
        return "\n".join([head] + [f"  {f}" for f in picked])

    def to_dict(self) -> dict[str, Any]:
        exp = {k: _jsonable(v) for k, v in self.expectations.items()}
        return {"config": self.config,
                "expectations": exp,
                "rules_run": list(self.rules_run),
                "findings": [f.to_dict() for f in self.findings],
                "waived": [f.to_dict() for f in self.waived]}

    def to_json(self, **kwargs: Any) -> str:
        return json.dumps(self.to_dict(), default=str, **kwargs)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    if isinstance(v, Mapping):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def no_findings(report_or_findings: "Report | Iterable[Finding]",
                rule: Optional[str] = None,
                min_severity: Severity = Severity.WARNING) -> bool:
    """Functional form for tests: ``assert no_findings(report, rule=...)``."""
    if isinstance(report_or_findings, Report):
        return report_or_findings.no_findings(rule, min_severity)
    rep = Report(findings=list(report_or_findings))
    return rep.no_findings(rule, min_severity)


def apply_waivers(findings: Sequence[Finding],
                  expectations: Mapping[str, Any],
                  waivers: Sequence[Waiver]
                  ) -> tuple[list[Finding], list[Finding]]:
    """Split findings into (kept, waived) under ``waivers``."""
    kept: list[Finding] = []
    muted: list[Finding] = []
    for f in findings:
        if any(w.matches(f, expectations) for w in waivers):
            muted.append(f)
        else:
            kept.append(f)
    return kept, muted
