"""Bridge from a built ``ParallelADMMTrainer`` to an analysis run.

``trainer_expectations`` distils the trainer's *host-side* contract —
transport mode, exchange-plan rounds, scheduled wire bytes, layout shape
facts, donation intent, kernel specs — into the expectations dict the
rule registry checks the *compiled program* against.  ``analyze_trainer``
lowers/compiles the step (or reuses a caller-supplied HLO dump), traces
the jaxpr, and runs the registry.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import numpy as np

from repro.analysis.findings import Report, Waiver
from repro.analysis.registry import AnalysisContext, run_rules


def _gathered_cs(cfg: Any) -> list[int]:
    """The per-iteration gather payload widths (the same convention as
    the trainer's ``comm_stats``): Z_0 once, Z_1..Z_L, q per hidden
    layer, then U and the penultimate-Z refresh for L >= 2."""
    dims = list(cfg.layer_dims)
    cs = [dims[0]] + dims[1:]
    if cfg.num_layers >= 2:
        cs += dims[2:] + [dims[-1], dims[-2]]
    return cs


def _kernel_entries(tr: Any, n_shards: int) -> list[dict]:
    """One ELL-kernel spec per shard, with that shard's scalar operands
    (localized indices under multi-shard p2p, global ids otherwise)."""
    from repro.kernels.community_spmm import (ell_fused_spec,
                                              ell_packed_spec, ell_spec)

    data = tr.data
    if data.ell_blocks is None:
        return []
    m, max_deg, n_pad, _ = data.ell_blocks.shape
    k = m // n_shards
    idx = np.asarray(data.ell_indices)
    z_lanes = m
    packed_wire = bool(getattr(tr, "packed", False)
                       and n_shards > 1 and tr._plan is not None)
    if tr.transport == "p2p" and n_shards > 1 and tr._plan is not None:
        csr = tr.layout.compress()
        idx = tr._plan.localize_indices(csr.ell_indices, csr.ell_mask)
        z_lanes = tr._plan.r_pad
    msk = np.asarray(data.ell_mask)
    rows = np.asarray(data.row_counts)
    nbrs = np.asarray(data.nbr_counts)
    c = max(tr.cfg.layer_dims)
    if packed_wire:
        csr = tr.layout.compress()
        off = np.asarray(tr._plan.localized_offsets(csr.ell_indices,
                                                    csr.ell_mask))
        off8 = np.where(msk != 0, off // 8, 0).astype(np.int32)
    entries = []
    for s in range(n_shards):
        sl = slice(s * k, (s + 1) * k)
        if packed_wire:
            # the packed trainer's aggregation reads the receive *plane*
            # through 8-row offsets, not a strided (z_lanes, n_pad, C)
            spec = ell_packed_spec(
                k, max_deg, n_pad, c, tr._plan.recv_plane_rows,
                block_bytes=data.ell_blocks.dtype.itemsize, z_bytes=4)
            scalars = {"ell_offsets8": off8[sl], "ell_mask": msk[sl],
                       "row_counts": rows[sl], "nbr_counts": nbrs[sl]}
            if getattr(getattr(tr, "config", None), "fused", False):
                # the fused aggregation→GEMM pass shares the packed
                # scalars; widest feature pair bounds its VMEM footprint
                fspec = ell_fused_spec(
                    k, max_deg, n_pad, c, c, tr._plan.recv_plane_rows,
                    block_bytes=data.ell_blocks.dtype.itemsize, z_bytes=4)
                entries.append({"spec": fspec, "scalars": dict(scalars)})
        else:
            spec = ell_spec(k, max_deg, n_pad, c, z_lanes,
                            block_bytes=data.ell_blocks.dtype.itemsize,
                            z_bytes=4)
            scalars = {"ell_indices": idx[sl], "ell_mask": msk[sl],
                       "row_counts": rows[sl], "nbr_counts": nbrs[sl]}
        entries.append({"spec": spec, "scalars": scalars})
    return entries


def trainer_expectations(tr: Any) -> dict[str, Any]:
    """Expectations dict for the built-in rules, from the trainer's
    host-side plan and layout (see ``AnalysisContext`` for the keys)."""
    from repro.core.parallel import AXIS

    n_shards = tr.mesh.shape[AXIS]
    m = tr.data.num_parts
    n_pad = tr.layout.n_pad
    cs = _gathered_cs(tr.cfg)
    max_c = max(tr.cfg.layer_dims)
    if tr.data.ell_mask is not None:
        max_deg = int(tr.data.ell_mask.shape[1])
    else:
        max_deg = m
    exp: dict[str, Any] = {
        "pad_mode": tr.pad_mode,
        "compressed": tr.compressed,
        "m_total": m,
        "n_shards": n_shards,
        "lanes": m // n_shards,
        "n_pad": n_pad,
        "max_deg": max_deg,
        "num_gathers": len(cs),
        "dense_adjacency_allowed": not tr.compressed,
        "expect_donated": (".zs", ".u"),
    }
    # the minibatch trainer's compiled step runs a *restricted* round
    # schedule (messages.restrict_exchange): expectations come from the
    # active sub-plan, so the permute-schedule rule proves the sampled
    # program touches no unsampled shard pair
    plan = getattr(tr, "_active_plan", None) or tr._plan
    if n_shards > 1:
        # single-shard meshes compile no real collectives; the transport
        # contract is only meaningful (and checkable) on >1 shards
        exp["transport"] = tr.transport
        if tr.transport == "p2p":
            if plan is not tr._plan:
                from repro.core import messages
                bf16 = bool(getattr(getattr(tr, "config", None),
                                    "comm_bf16", False))
                wire = messages.exchange_bytes(
                    plan, cs, itemsize=2 if bf16 else 4)
                exp["collective_budget_bytes"] = int(wire["wire_bytes"])
            else:
                exp["collective_budget_bytes"] = \
                    int(tr.comm_stats["wire_bytes"])
        else:
            exp["collective_budget_bytes"] = int(tr.comm_stats["full_bytes"])
        if plan is not None:
            exp["round_pairs"] = [tuple(r.pairs) for r in plan.rounds]
        # the only legitimate all-reduces are the W-update psums: weight
        # gradients and line-search scalars, possibly combined by XLA
        w_bytes = sum(int(np.prod(w.shape)) * w.dtype.itemsize
                      for w in tr.state.weights)
        exp["allreduce_max_bytes"] = 2 * w_bytes + 4096
    # packed resident state: only meaningful when the packed plane actually
    # feeds the wire (multi-shard p2p) — the 1-shard packed program keeps
    # the well-tested blocked body
    exp["state_packed"] = bool(getattr(tr, "packed", False)
                               and tr.transport == "p2p" and n_shards > 1
                               and tr._plan is not None)
    if exp["state_packed"]:
        exp["packed_rows_bound"] = int(tr._plan.r_pad)
    # fused aggregation→GEMM: only the W-update may hand an aggregated
    # block stack to a dot (its line search re-evaluates the GEMM under a
    # varying W) — one aggregate per layer; every Z-update site must run
    # the fused/reassociated form.  Like state_packed, only meaningful
    # when the packed plane feeds the wire.
    exp["fused"] = bool(exp["state_packed"]
                        and getattr(getattr(tr, "config", None),
                                    "fused", False))
    if exp["fused"]:
        exp["fused_max_agg_handoffs"] = int(tr.cfg.num_layers)
    # largest legitimate resident buffers: the adjacency store, the full
    # Z/U state stack, and one gathered payload; anything 4x past their
    # max is a blow-up
    state_bytes = sum(int(np.prod(z.shape)) * z.dtype.itemsize
                      for z in tr.state.zs) + int(np.prod(tr.state.u.shape)
                                                  ) * tr.state.u.dtype.itemsize
    gather_stack = m * n_pad * max_c * 4
    exp["hbm_intermediate_budget"] = 4 * max(
        int(tr.data.adjacency_nbytes), state_bytes, gather_stack)
    if tr.compressed:
        exp["kernels"] = _kernel_entries(tr, n_shards)
    return exp


def _donation_map(lowered: Any) -> dict[str, bool]:
    """{tree path: donated} from ``lowered.args_info``."""
    import jax

    out: dict[str, bool] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(lowered.args_info)
    for path, info in flat:
        key = "".join(str(p) for p in path)
        out[key] = bool(getattr(info, "donated", False))
    return out


def analyze_trainer(tr: Any, *,
                    hlo_text: Optional[str] = None,
                    config: str = "",
                    rules: Optional[Sequence[str]] = None,
                    waivers: Sequence[Waiver] = (),
                    with_jaxpr: bool = True) -> Report:
    """Run the rule registry over a trainer's compiled step.

    Pass ``hlo_text`` to reuse an already-compiled dump (the p2p proof
    subprocess compiles once and both asserts and lints the same text);
    otherwise the step is lowered and compiled here.
    """
    import jax

    exp = trainer_expectations(tr)
    # minibatch steps take (state, nbr_decay); _analysis_args is the
    # trainer's own account of its compiled step's signature
    args = getattr(tr, "_analysis_args", None) or (tr.state,)
    lowered = tr._step.lower(*args)
    exp["args_donated"] = _donation_map(lowered)
    if hlo_text is None:
        hlo_text = lowered.compile().as_text()
    jaxpr = None
    if with_jaxpr:
        jaxpr = jax.make_jaxpr(tr._step)(*args)
    ctx = AnalysisContext(hlo_text=hlo_text, jaxpr=jaxpr,
                          expectations=exp,
                          config=config or f"{tr.transport}/{tr.pad_mode}")
    return run_rules(ctx, rules=rules, waivers=waivers)
