"""repro.analysis — a jaxpr/HLO invariant linter.

Statically proves the transport, memory, precision, and Pallas-kernel
guarantees the trainer configs rely on (see docs/analysis.md for the
rule catalogue).  Entry points:

  * ``analyze_trainer(tr)`` — lint a built ``ParallelADMMTrainer``'s
    compiled step against its own host-side plan;
  * ``analyze_hlo(text, expectations)`` — lint any HLO dump;
  * ``no_findings(report, rule=...)`` — the pytest-side assertion;
  * ``launch/analyze.py`` — the CLI over the benchmark configs.
"""
from repro.analysis.findings import (Finding, Report, Severity, Waiver,
                                     no_findings)
from repro.analysis.registry import (AnalysisContext, Rule, all_rules,
                                     analyze_hlo, get_rule, rule, run_rules)
from repro.analysis.trainer import analyze_trainer, trainer_expectations

__all__ = [
    "AnalysisContext", "Finding", "Report", "Rule", "Severity", "Waiver",
    "all_rules", "analyze_hlo", "analyze_trainer", "get_rule",
    "no_findings", "rule", "run_rules", "trainer_expectations",
]
