"""Memory rules: nothing dense-adjacency-shaped, nothing over budget,
donated hot-loop buffers, no host round-trips.

PR 2's win was replacing the (M, M, n_pad, n_pad) dense adjacency with
block-compressed storage; these rules keep any program from silently
re-materialising it (or any other HBM blow-up) in an intermediate.
"""
from __future__ import annotations

from typing import Iterable

from repro.analysis.findings import Finding, Severity
from repro.analysis.registry import AnalysisContext, rule
from repro.analysis.rules.precision import _sub_jaxprs


@rule("memory/no-dense-adjacency")
def no_dense_adjacency(ctx: AnalysisContext) -> Iterable[Finding]:
    """No intermediate shaped like a dense block-adjacency row stack:
    trailing dims (n_pad, n_pad) with more leading blocks than the ELL
    bound lanes x max_deg allows."""
    exp = ctx.expectations
    n_pad = exp.get("n_pad")
    if ctx.hlo_text is None or not n_pad:
        return
    if exp.get("dense_adjacency_allowed"):
        return
    lanes = exp.get("lanes", 1)
    m_total = exp.get("m_total", 1)
    max_deg = exp.get("max_deg", m_total)
    # inputs may legitimately hold the full-M ELL block store (the trainer
    # closes over it); anything *computed* is bound by one shard's ELL
    # working set
    input_blocks = max(int(m_total) * int(max_deg), 1)
    compute_blocks = max(int(lanes) * int(max_deg), 1)
    for comp, ins in ctx.instructions():
        dims = ins.result_dims
        if len(dims) < 3 or dims[-1] != n_pad or dims[-2] != n_pad:
            continue
        blocks = 1
        for d in dims[:-2]:
            blocks *= d
        allowed_blocks = input_blocks if ins.op in ("parameter", "constant") \
            else compute_blocks
        if blocks > allowed_blocks:
            yield Finding(
                "memory/no-dense-adjacency", Severity.ERROR,
                f"%{ins.name} ({ins.op}) materialises {blocks} "
                f"({n_pad}x{n_pad}) blocks — dense-adjacency shaped; the "
                f"ELL bound is lanes x max_deg = {allowed_blocks}",
                location=ins.name,
                details={"shape": list(dims), "blocks": blocks,
                         "allowed_blocks": allowed_blocks,
                         "computation": comp.name})


@rule("memory/packed-resident-state")
def packed_resident_state(ctx: AnalysisContext) -> Iterable[Finding]:
    """Under packed state (``ParallelADMMTrainer(packed=True)`` on a
    multi-shard p2p mesh) the per-shard program never materialises a
    blocked row stack taller than the receive buffer: any computed
    (rows, n_pad, C) intermediate with ``rows > r_pad`` is a strided
    (M, n_pad, C)-shaped payload sneaking back in — exactly what the
    packed plane exists to retire."""
    exp = ctx.expectations
    n_pad = exp.get("n_pad")
    if ctx.hlo_text is None or not n_pad:
        return
    if not exp.get("state_packed"):
        return
    bound = int(exp.get("packed_rows_bound", 0))
    if bound <= 0:
        return
    for comp, ins in ctx.instructions():
        dims = ins.result_dims
        # blocked row stacks only: (rows, n_pad, C) with a feature-like
        # trailing dim (C == n_pad would be an adjacency block, which
        # memory/no-dense-adjacency already bounds)
        if len(dims) != 3 or dims[-2] != n_pad or dims[-1] == n_pad:
            continue
        if ins.op in ("parameter", "constant"):
            continue
        if dims[0] > bound:
            yield Finding(
                "memory/packed-resident-state", Severity.ERROR,
                f"%{ins.name} ({ins.op}) materialises a ({dims[0]}, "
                f"{n_pad}, {dims[-1]}) blocked row stack — taller than "
                f"the r_pad={bound} receive view the packed layout "
                f"allows per shard",
                location=ins.name,
                details={"shape": list(dims), "rows": dims[0],
                         "packed_rows_bound": bound,
                         "computation": comp.name})


def fused_agg_handoffs(closed_jaxpr: "object", n_pad: int) -> list[dict]:
    """Aggregated block stacks handed to a GEMM, from a dataflow walk.

    An *aggregation* is any equation consuming an ELL-block-shaped
    operand (trailing dims (n_pad, n_pad), rank ≥ 4 — the einsum oracle's
    block store or a pallas_call's block operand) whose output is a 3-D
    ``(rows, n_pad, C ≠ n_pad)`` stack.  The taint follows the stack
    only through ``add``/casts (the overlap path sums per-group partials
    before consuming them) — NOT through arbitrary shape-preserving ops,
    or the fused sites' own outputs would leak taint down activation and
    cotangent chains into the lane solvers' dots.  A *handoff* is
    recorded when a tainted var feeds a ``dot_general`` — each distinct
    stack counted once, however many dots the autodiff machinery derives
    from it.  Importable directly (tests); the registry rule wraps it.
    """
    handoffs: list[dict] = []
    seen: set[int] = set()
    carriers = {"add", "convert_element_type", "copy"}

    def shp(v):
        return tuple(getattr(getattr(v, "aval", None), "shape", ()) or ())

    def walk(jaxpr, path: str) -> None:
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        tainted: dict[int, dict] = {}
        consumed: dict[int, dict] = {}
        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            loc = f"{path}eqns[{i}]:{name}"
            if name == "dot_general":
                for v in eqn.invars:
                    if id(v) in tainted and id(v) not in consumed:
                        consumed[id(v)] = dict(tainted[id(v)], dot=loc)
            has_blocks = any(
                len(s) >= 4 and s[-1] == n_pad and s[-2] == n_pad
                for s in (shp(v) for v in eqn.invars))
            for v in eqn.outvars:
                s = shp(v)
                if len(s) != 3 or s[-2] != n_pad or s[-1] == n_pad:
                    continue
                if has_blocks:
                    tainted[id(v)] = {"producer": loc, "shape": list(s)}
                elif name in carriers:
                    src = next((tainted[id(u)] for u in eqn.invars
                                if id(u) in tainted), None)
                    if src is not None:
                        tainted[id(v)] = src
            for sub in _sub_jaxprs(eqn.params):
                walk(sub, loc + "/")
        handoffs.extend(consumed.values())

    walk(getattr(closed_jaxpr, "jaxpr", closed_jaxpr), "")
    return handoffs


@rule("memory/fused-no-intermediate")
def fused_no_intermediate(ctx: AnalysisContext) -> Iterable[Finding]:
    """Under ``TrainerConfig(fused=True)`` the compiled step materialises
    no HBM-resident aggregated ``(rows, n_pad, C)`` stack feeding a GEMM
    beyond the W-update allowance (one per layer — its line search
    legitimately re-reads the aggregate under a varying W).  Checked on
    the traced jaxpr, where the handoff survives on every dispatch
    target: the TPU program would show a pallas_call output into a dot,
    the CPU oracle an einsum output into a dot — the fused kernel keeps
    the aggregate in VMEM scratch and the fused oracle reassociates it
    away, so either way the count stays at the W-update floor."""
    exp = ctx.expectations
    n_pad = exp.get("n_pad")
    if ctx.jaxpr is None or not n_pad or not exp.get("fused"):
        return
    allowed = int(exp.get("fused_max_agg_handoffs", 0))
    found = fused_agg_handoffs(ctx.jaxpr, int(n_pad))
    if len(found) > allowed:
        yield Finding(
            "memory/fused-no-intermediate", Severity.ERROR,
            f"{len(found)} aggregated (rows, {n_pad}, C) stacks feed a "
            f"dot_general — the fused step allows {allowed} (the "
            f"W-update line-search aggregates); extra handoffs mean an "
            f"unfused aggregation→GEMM site materialises its aggregate",
            location=found[0].get("dot"),
            details={"handoffs": found[:16],
                     "allowed": allowed, "count": len(found)})


@rule("memory/hbm-intermediate-budget")
def hbm_intermediate_budget(ctx: AnalysisContext) -> Iterable[Finding]:
    """No single intermediate exceeds ``hbm_intermediate_budget`` bytes."""
    budget = ctx.expectations.get("hbm_intermediate_budget")
    if ctx.hlo_text is None or budget is None:
        return
    for comp, ins in ctx.instructions():
        nbytes = max(ins.result_bytes, ins.tuple_bytes)
        if nbytes > budget and ins.op not in ("tuple", "parameter"):
            yield Finding(
                "memory/hbm-intermediate-budget", Severity.ERROR,
                f"%{ins.name} ({ins.op}) holds {nbytes} B "
                f"> budget {int(budget)} B",
                location=ins.name,
                details={"bytes": nbytes, "budget": int(budget),
                         "shape": list(ins.result_dims),
                         "computation": comp.name})


@rule("memory/no-full-graph-tensors")
def no_full_graph_tensors(ctx: AnalysisContext) -> Iterable[Finding]:
    """Under ``full_graph_rows`` no instruction — parameters included —
    holds a tensor whose leading dim reaches the full-graph row count.
    The serving hit path touches one community block and one request-row
    vector; a full-plane (Σ-bucket-rows) or (N, ...) operand means the
    program secretly depends on the whole graph and its latency will
    scale with it."""
    bound = ctx.expectations.get("full_graph_rows")
    if ctx.hlo_text is None or not bound:
        return
    for comp, ins in ctx.instructions():
        dims = ins.result_dims
        if not dims or ins.op == "tuple":
            continue
        if dims[0] >= int(bound):
            yield Finding(
                "memory/no-full-graph-tensors", Severity.ERROR,
                f"%{ins.name} ({ins.op}) holds a {list(dims)} tensor — "
                f"leading dim >= the full-graph row bound {int(bound)}",
                location=ins.name,
                details={"shape": list(dims), "bound": int(bound),
                         "computation": comp.name})


@rule("memory/donated-inputs")
def donated_inputs(ctx: AnalysisContext) -> Iterable[Finding]:
    """The trainer-step jit donates its state (Z/U stacks rebind every
    step; un-donated they double peak HBM)."""
    donated = ctx.expectations.get("args_donated")
    want = ctx.expectations.get("expect_donated")
    if not donated or not want:
        return
    for needle in want:
        matching = {p: d for p, d in donated.items()
                    if needle.lower() in p.lower()}
        if not matching:
            yield Finding(
                "memory/donated-inputs", Severity.WARNING,
                f"no trainer-step argument matches '{needle}' — "
                f"donation expectation is stale",
                details={"expected": needle,
                         "args": sorted(donated)[:16]})
            continue
        undonated = sorted(p for p, d in matching.items() if not d)
        if undonated:
            yield Finding(
                "memory/donated-inputs", Severity.ERROR,
                f"{len(undonated)} '{needle}' buffer(s) not donated to the "
                f"step jit (first: {undonated[0]})",
                location=undonated[0],
                details={"expected": needle, "undonated": undonated[:16]})


_HOST_OPS = {"infeed", "outfeed", "send", "recv", "send-done", "recv-done"}
_HOST_TARGETS = ("callback", "host", "Infeed", "Outfeed")


@rule("memory/host-transfer")
def host_transfer(ctx: AnalysisContext) -> Iterable[Finding]:
    """The compiled step makes no host<->device round-trips (infeed/
    outfeed/send/recv or host-callback custom-calls in the hot loop)."""
    if ctx.hlo_text is None:
        return
    for comp, ins in ctx.instructions():
        hit = ins.op in _HOST_OPS
        if not hit and ins.op == "custom-call":
            hit = any(t in ins.attrs for t in _HOST_TARGETS)
        if hit:
            yield Finding(
                "memory/host-transfer", Severity.ERROR,
                f"%{ins.name} ({ins.op}) transfers to/from host inside "
                f"the compiled step",
                location=ins.name,
                details={"computation": comp.name,
                         "attrs": ins.attrs[:160]})
