"""Precision rules: bf16 stays on the wire/storage side, never in the
accumulator.

PR 5's mixed-precision contract: bf16 is a *transport and storage* format
(wire payloads, ELL blocks) while every dot/reduce accumulates in f32.
These rules prove it two ways — a dataflow walk over the traced jaxpr
(catches a missing ``preferred_element_type`` before XLA ever runs) and a
scan over the optimized HLO (catches what the compiler actually emitted).
"""
from __future__ import annotations

from typing import Any, Iterable, List

from repro.analysis.findings import Finding, Severity
from repro.analysis.registry import AnalysisContext, rule

_LOW = ("bf16", "f16")
_WIDE = ("f64", "c128")


def _dtype_map(ctx: AnalysisContext) -> dict[str, str]:
    return {ins.name: ins.dtype for _, ins in ctx.instructions()}


@rule("precision/bf16-dot-accumulate")
def bf16_dot_accumulate(ctx: AnalysisContext) -> Iterable[Finding]:
    """Every dot fed bf16/f16 operands accumulates in f32 (an
    ``f32 dot(bf16, bf16)`` is the blessed pattern; a bf16-result dot
    silently rounds every partial sum)."""
    if ctx.hlo_text is None:
        return
    dtypes = _dtype_map(ctx)
    for comp, ins in ctx.instructions():
        if ins.op != "dot":
            continue
        low_in = [o for o in ins.operands if dtypes.get(o) in _LOW]
        if low_in and ins.dtype in _LOW:
            yield Finding(
                "precision/bf16-dot-accumulate", Severity.ERROR,
                f"%{ins.name}: dot over {ins.dtype} operands accumulates "
                f"in {ins.dtype} (no f32 upcast)",
                location=ins.name,
                details={"computation": comp.name,
                         "operand_dtypes": [dtypes.get(o, "?")
                                            for o in ins.operands],
                         "result_dtype": ins.dtype})


@rule("precision/bf16-reduce", severity=Severity.WARNING)
def bf16_reduce(ctx: AnalysisContext) -> Iterable[Finding]:
    """Reductions over bf16 carry the accumulator in f32 (warning: XLA
    sometimes keeps small reduces in bf16 harmlessly)."""
    if ctx.hlo_text is None:
        return
    dtypes = _dtype_map(ctx)
    for comp, ins in ctx.instructions():
        if ins.op != "reduce" or ins.dtype not in _LOW:
            continue
        if any(dtypes.get(o) in _LOW for o in ins.operands):
            yield Finding(
                "precision/bf16-reduce", Severity.WARNING,
                f"%{ins.name}: reduce accumulates in {ins.dtype}",
                location=ins.name,
                details={"computation": comp.name,
                         "result_dtype": ins.dtype})


@rule("precision/no-f64")
def no_f64(ctx: AnalysisContext) -> Iterable[Finding]:
    """No f64/c128 values anywhere in the compiled step (an accidental
    Python-float promotion doubles bytes on wire and in HBM)."""
    if ctx.hlo_text is None or ctx.expectations.get("allow_f64"):
        return
    for comp, ins in ctx.instructions():
        if ins.dtype in _WIDE:
            yield Finding(
                "precision/no-f64", Severity.ERROR,
                f"%{ins.name} ({ins.op}) is {ins.dtype}",
                location=ins.name,
                details={"computation": comp.name,
                         "shape": list(ins.result_dims)})


# --- jaxpr dataflow walk ---------------------------------------------------

def check_jaxpr_precision(closed_jaxpr: Any,
                          allow_f64: bool = False) -> List[Finding]:
    """Recursive dataflow walk over a ClosedJaxpr: flag bf16 dots without
    an f32 ``preferred_element_type``, bf16 reduce accumulators, and
    f64 avals.  Importable directly for ad-hoc checks; the registry rule
    wraps it when the context carries a jaxpr."""
    import numpy as np

    findings: list[Finding] = []
    seen: set[int] = set()

    def dt(v: Any) -> Any:
        return getattr(getattr(v, "aval", None), "dtype", None)

    def is_low(v: Any) -> bool:
        d = dt(v)
        return d is not None and str(d) in ("bfloat16", "float16")

    def walk(jaxpr: Any, path: str) -> None:
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            loc = f"{path}eqns[{i}]:{name}"
            if not allow_f64:
                for v in list(eqn.invars) + list(eqn.outvars):
                    d = dt(v)
                    if d is not None and str(d) in ("float64", "complex128"):
                        findings.append(Finding(
                            "precision/jaxpr-dataflow", Severity.ERROR,
                            f"{name} carries {d} (x64 leak)",
                            location=loc, details={"dtype": str(d)}))
                        break
            if name == "dot_general" and any(is_low(v) for v in eqn.invars):
                pref = eqn.params.get("preferred_element_type")
                out_low = any(is_low(v) for v in eqn.outvars)
                if out_low and (pref is None or str(np.dtype(pref)) not in
                                ("float32", "float64")):
                    findings.append(Finding(
                        "precision/jaxpr-dataflow", Severity.ERROR,
                        "dot_general over bf16/f16 operands has no f32 "
                        "preferred_element_type (accumulates narrow)",
                        location=loc,
                        details={"preferred_element_type": str(pref)}))
            if name in ("reduce_sum", "cumsum") and \
                    any(is_low(v) for v in eqn.outvars):
                findings.append(Finding(
                    "precision/jaxpr-dataflow", Severity.WARNING,
                    f"{name} accumulates in bf16/f16",
                    location=loc, details={}))
            for sub in _sub_jaxprs(eqn.params):
                walk(sub, loc + "/")

    inner = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    walk(inner, "")
    return findings


def _sub_jaxprs(params: dict) -> Iterable[Any]:
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            x = getattr(x, "jaxpr", x)
            if hasattr(x, "eqns"):
                yield x


@rule("precision/jaxpr-dataflow")
def jaxpr_dataflow(ctx: AnalysisContext) -> Iterable[Finding]:
    """Dataflow walk over the traced jaxpr: bf16 into dot/reduce without
    f32 upcast, and f64 leaks, caught before compilation."""
    if ctx.jaxpr is None:
        return
    yield from check_jaxpr_precision(
        ctx.jaxpr, allow_f64=bool(ctx.expectations.get("allow_f64")))
