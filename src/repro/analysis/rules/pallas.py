"""Pallas kernel rules: block DMAs in bounds, VMEM within budget,
(8, 128)-aligned tiles.

These rules never run the kernel.  They abstract-interpret the
``KernelSpec`` the kernel itself is built from (``kernels/community_spmm``
exports ``spmm_spec``/``ell_spec``): each operand's index map is evaluated
at every grid *corner* (the maps are affine/monotone in the grid ids, so
extremes bound the interior) with the real scalar-prefetch arrays, and
data-dependent gathers (``ell_indices`` steering the Z DMA) are bounded by
the value range of the scalar array itself.

Context expectation: ``kernels`` is a list of dicts —

    {"spec": KernelSpec,                  # required
     "scalars": {name: np.ndarray, ...},  # the scalar-prefetch operands
     "vmem_budget": int}                  # optional, default 16 MiB
"""
from __future__ import annotations

import itertools
from typing import Any, Iterable, List, Mapping, Optional

from repro.analysis.findings import Finding, Severity
from repro.analysis.registry import AnalysisContext, rule

VMEM_BUDGET_BYTES = 16 * 1024 * 1024    # per-core VMEM on current TPUs
_SUBLANE, _LANE = 8, 128


def _grid_corners(grid: tuple) -> Iterable[tuple]:
    axes = [sorted({0, g - 1}) for g in grid]
    return itertools.product(*axes)


def check_kernel_bounds(spec: Any,
                        scalars: Optional[Mapping[str, Any]] = None
                        ) -> List[Finding]:
    """Every block index the grid can produce stays inside its operand.

    Importable directly (tests hand-build bad specs); the registry rule
    wraps it over ``expectations["kernels"]``.
    """
    scalars = scalars or {}
    findings: list[Finding] = []
    scalar_args = [scalars.get(n) for n in spec.scalar_prefetch]
    have_scalars = all(a is not None for a in scalar_args)
    for op in spec.operands:
        counts = op.block_counts()
        if op.index_map.__code__.co_argcount > len(spec.grid) \
                and not have_scalars:
            continue                     # cannot evaluate without scalars
        for corner in _grid_corners(spec.grid):
            try:
                idx = op.index_map(*corner, *scalar_args)
            except (IndexError, TypeError) as e:
                findings.append(Finding(
                    "pallas/index-bounds", Severity.ERROR,
                    f"{spec.name}:{op.name} index map failed at grid "
                    f"{corner}: {e}", location=f"{spec.name}:{op.name}",
                    details={"grid_point": list(corner)}))
                break
            bad = [(ax, int(v), int(c))
                   for ax, (v, c) in enumerate(zip(idx, counts))
                   if not 0 <= int(v) < c]
            if bad:
                ax, v, c = bad[0]
                findings.append(Finding(
                    "pallas/index-bounds", Severity.ERROR,
                    f"{spec.name}:{op.name} block index {v} out of range "
                    f"[0, {c}) on dim {ax} at grid point {corner}",
                    location=f"{spec.name}:{op.name}",
                    details={"grid_point": list(corner), "dim": ax,
                             "index": v, "blocks": c}))
                break
        if op.gather_scalar and op.gather_scalar in scalars:
            arr = scalars[op.gather_scalar]
            lo, hi = int(arr.min()), int(arr.max())
            limit = counts[0]
            if lo < 0 or hi >= limit:
                findings.append(Finding(
                    "pallas/index-bounds", Severity.ERROR,
                    f"{spec.name}:{op.name} gathered via "
                    f"{op.gather_scalar} with values in [{lo}, {hi}] but "
                    f"only {limit} leading blocks",
                    location=f"{spec.name}:{op.name}",
                    details={"scalar": op.gather_scalar, "min": lo,
                             "max": hi, "blocks": limit}))
    return findings


def check_kernel_vmem(spec: Any,
                      budget: int = VMEM_BUDGET_BYTES) -> List[Finding]:
    """Double-buffered block footprint + scratch fits the VMEM budget."""
    est = spec.vmem_bytes()
    if est > budget:
        return [Finding(
            "pallas/vmem-budget", Severity.ERROR,
            f"{spec.name}: estimated VMEM footprint {est} B exceeds "
            f"budget {budget} B",
            location=spec.name,
            details={"estimate": int(est), "budget": int(budget),
                     "per_operand": {op.name: op.block_bytes()
                                     for op in spec.operands},
                     "scratch": spec.scratch_bytes})]
    return []


def check_tile_alignment(spec: Any) -> List[Finding]:
    """Trailing block dims are (8, 128)-aligned (or span the full array
    dim) so blocks map onto whole VREG tiles."""
    findings: list[Finding] = []
    for op in spec.operands:
        pairs = [(b, d) for b, d in zip(op.block_shape, op.array_shape)
                 if b is not None]
        if len(pairs) < 2:
            continue
        (sub_b, sub_d), (lane_b, lane_d) = pairs[-2], pairs[-1]
        bad = []
        if lane_b % _LANE and lane_b != lane_d:
            bad.append(f"lane dim {lane_b} not a multiple of {_LANE}")
        if sub_b % _SUBLANE and sub_b != sub_d:
            bad.append(f"sublane dim {sub_b} not a multiple of {_SUBLANE}")
        if bad:
            findings.append(Finding(
                "pallas/tile-alignment", Severity.WARNING,
                f"{spec.name}:{op.name} block "
                f"{tuple(b for b in op.block_shape)}: " + "; ".join(bad),
                location=f"{spec.name}:{op.name}",
                details={"block_shape": [b for b in op.block_shape]}))
    return findings


def _kernels(ctx: AnalysisContext) -> list[dict]:
    return list(ctx.expectations.get("kernels") or [])


@rule("pallas/index-bounds")
def index_bounds(ctx: AnalysisContext) -> Iterable[Finding]:
    """Abstract interpretation of each kernel's index maps (grid corners
    + scalar-prefetch value ranges) proves every block DMA in bounds."""
    for k in _kernels(ctx):
        yield from check_kernel_bounds(k["spec"], k.get("scalars"))


@rule("pallas/vmem-budget")
def vmem_budget(ctx: AnalysisContext) -> Iterable[Finding]:
    """Each kernel's estimated VMEM footprint fits its budget."""
    for k in _kernels(ctx):
        yield from check_kernel_vmem(
            k["spec"], k.get("vmem_budget", VMEM_BUDGET_BYTES))


@rule("pallas/tile-alignment", severity=Severity.WARNING)
def tile_alignment(ctx: AnalysisContext) -> Iterable[Finding]:
    """Block shapes land on (8, 128) VREG tile boundaries."""
    for k in _kernels(ctx):
        yield from check_tile_alignment(k["spec"])
