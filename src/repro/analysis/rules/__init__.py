"""Built-in rule families.  Importing a module registers its rules."""
from repro.analysis.rules import collective, memory, pallas, precision

__all__ = ["collective", "memory", "pallas", "precision"]
