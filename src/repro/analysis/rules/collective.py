"""Collective rules: the transport contract, statically.

PR 3's headline — p2p transport compiles to collective-permutes only, with
the permute schedule and payload bytes matching the host-side
``NeighborExchange`` plan — is re-proved here against any compiled HLO,
not just the one config a test happens to build.
"""
from __future__ import annotations

from typing import Iterable, Iterator

from repro.analysis import hlo as H
from repro.analysis.findings import Finding, Severity
from repro.analysis.registry import AnalysisContext, rule


def _collective_instrs(ctx: AnalysisContext,
                       base: str) -> Iterator[tuple[H.Computation, H.Instr]]:
    """All instrs whose base op (start/done folded) equals ``base``,
    skipping the -done halves so async pairs count once."""
    for comp, ins in ctx.instructions():
        if ins.op.endswith("-done"):
            continue
        if H.base_op(ins) == base:
            yield comp, ins


@rule("collective/no-allgather-under-p2p")
def no_allgather_under_p2p(ctx: AnalysisContext) -> Iterable[Finding]:
    """Under ``transport="p2p"`` the compiled step contains no all-gather."""
    if ctx.hlo_text is None or ctx.expectations.get("transport") != "p2p":
        return
    hits = list(_collective_instrs(ctx, "all-gather"))
    if hits:
        yield Finding(
            "collective/no-allgather-under-p2p", Severity.ERROR,
            f"{len(hits)} all-gather op(s) compiled under p2p transport "
            f"(first: %{hits[0][1].name} in {hits[0][0].name})",
            location=hits[0][1].name,
            details={"count": len(hits),
                     "instructions": [i.name for _, i in hits[:8]]})


_ALL_COLLECTIVES = ("all-gather", "all-reduce", "collective-permute",
                    "all-to-all", "reduce-scatter", "collective-broadcast")


@rule("collective/zero-collectives")
def zero_collectives(ctx: AnalysisContext) -> Iterable[Finding]:
    """Under ``expect_zero_collectives`` the program contains no
    collective of any kind — the serving hit/recompute paths are
    single-device programs over one resident plane, so any collective is
    a sharded-training construct leaking into the serving build."""
    if ctx.hlo_text is None or \
            not ctx.expectations.get("expect_zero_collectives"):
        return
    hits = [(comp, ins) for base in _ALL_COLLECTIVES
            for comp, ins in _collective_instrs(ctx, base)]
    if hits:
        yield Finding(
            "collective/zero-collectives", Severity.ERROR,
            f"{len(hits)} collective op(s) in a program expected to be "
            f"collective-free (first: %{hits[0][1].name} "
            f"[{hits[0][1].op}] in {hits[0][0].name})",
            location=hits[0][1].name,
            details={"count": len(hits),
                     "instructions": [i.op for _, i in hits[:8]]})


@rule("collective/allreduce-payload")
def allreduce_payload(ctx: AnalysisContext) -> Iterable[Finding]:
    """Every all-reduce operand stays within ``allreduce_max_bytes``
    (the W-update psums move weight-matrix gradients and scalars — an
    all-reduce carrying feature-matrix-sized payload is a transport leak)."""
    budget = ctx.expectations.get("allreduce_max_bytes")
    if ctx.hlo_text is None or budget is None:
        return
    sizes = {ins.name: ins.result_bytes or ins.tuple_bytes
             for _, ins in ctx.instructions()}
    for comp, ins in _collective_instrs(ctx, "all-reduce"):
        nbytes = sum(sizes.get(o, 0) for o in ins.operands)
        if nbytes > budget:
            yield Finding(
                "collective/allreduce-payload", Severity.ERROR,
                f"all-reduce %{ins.name} moves {nbytes} B "
                f"> budget {budget} B",
                location=ins.name,
                details={"bytes": nbytes, "budget": int(budget),
                         "computation": comp.name})


@rule("collective/permute-schedule")
def permute_schedule(ctx: AnalysisContext) -> Iterable[Finding]:
    """The distinct ``source_target_pairs`` sets in the HLO equal the
    host-side exchange plan's per-round pair sets, both ways."""
    rounds = ctx.expectations.get("round_pairs")
    if ctx.hlo_text is None or not rounds:
        return
    want = {frozenset(tuple(p) for p in r) for r in rounds}
    got: set[frozenset] = set()
    for _, ins in _collective_instrs(ctx, "collective-permute"):
        pairs = H.permute_pairs(ins)
        if pairs:
            got.add(pairs)
    if not got:
        yield Finding(
            "collective/permute-schedule", Severity.ERROR,
            f"no collective-permute compiled but the host plan has "
            f"{len(want)} round(s)",
            details={"planned_rounds": sorted(sorted(r) for r in want)})
        return
    extra = got - want
    missing = want - got
    if extra:
        yield Finding(
            "collective/permute-schedule", Severity.ERROR,
            f"{len(extra)} compiled permute pair-set(s) not in the host "
            f"plan: {sorted(sorted(s) for s in extra)[:3]}",
            details={"unplanned": sorted(sorted(s) for s in extra)})
    if missing:
        yield Finding(
            "collective/permute-schedule", Severity.ERROR,
            f"{len(missing)} planned round(s) never compiled: "
            f"{sorted(sorted(s) for s in missing)[:3]}",
            details={"missing": sorted(sorted(s) for s in missing)})


@rule("collective/permute-count", severity=Severity.WARNING)
def permute_count(ctx: AnalysisContext) -> Iterable[Finding]:
    """collective-permute count ≈ rounds × gathers (XLA may merge or split
    permutes, so a mismatch is a warning, not an error)."""
    rounds = ctx.expectations.get("round_pairs")
    gathers = ctx.expectations.get("num_gathers")
    if ctx.hlo_text is None or not rounds or not gathers:
        return
    n = sum(1 for _ in _collective_instrs(ctx, "collective-permute"))
    want = len(rounds) * gathers
    if n != want:
        yield Finding(
            "collective/permute-count", Severity.WARNING,
            f"{n} collective-permute op(s) compiled, expected "
            f"{len(rounds)} round(s) x {gathers} gather(s) = {want}",
            details={"compiled": n, "expected": want})


@rule("collective/payload-budget")
def payload_budget(ctx: AnalysisContext) -> Iterable[Finding]:
    """Trip-weighted transport payload bytes (gather/permute/alltoall/
    reduce-scatter, per the census) stay within the scheduled wire bound
    from ``verify_transport_bytes``."""
    budget = ctx.expectations.get("collective_budget_bytes")
    if ctx.hlo_text is None or budget is None:
        return
    census = ctx.census()
    transport_ops = ("all-gather", "collective-permute", "all-to-all",
                     "reduce-scatter")
    moved = sum(census.collectives[op]["bytes"] for op in transport_ops)
    if moved > budget:
        yield Finding(
            "collective/payload-budget", Severity.ERROR,
            f"compiled transport payload {moved:.0f} B exceeds the "
            f"scheduled bound {budget} B",
            details={"bytes": moved, "budget": int(budget),
                     "per_op": {op: census.collectives[op]["bytes"]
                                for op in transport_ops}})
