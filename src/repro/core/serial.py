"""Serial ADMM trainer (paper §4.1: one community, single agent).

The math is the global form of Algorithm 1; `parallel.py` implements the
community-distributed form and a test asserts both produce identical updates
(the paper's 'no performance loss' claim for community splitting).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gcn, graph, subproblems

Array = jax.Array


@dataclasses.dataclass
class TrainLog:
    epoch: list = dataclasses.field(default_factory=list)
    train_acc: list = dataclasses.field(default_factory=list)
    test_acc: list = dataclasses.field(default_factory=list)
    lagrangian: list = dataclasses.field(default_factory=list)
    residual: list = dataclasses.field(default_factory=list)
    epoch_time_s: list = dataclasses.field(default_factory=list)

    def as_dict(self):
        return dataclasses.asdict(self)


class SerialADMMTrainer:
    """Single-agent ADMM GCN trainer (the paper's 'Serial ADMM')."""

    def __init__(self, cfg: gcn.GCNConfig, admm: subproblems.ADMMConfig,
                 g: graph.Graph, seed: int = 0):
        self.cfg, self.admm, self.graph = cfg, admm, g
        self.a_tilde = jnp.asarray(
            graph.normalized_adjacency(g.num_nodes, g.edges))
        self.z0 = jnp.asarray(g.features)
        self.labels = jnp.asarray(g.labels)
        self.train_mask = jnp.asarray(g.train_mask, dtype=jnp.float32)
        self.test_mask = jnp.asarray(g.test_mask, dtype=jnp.float32)
        self.state = subproblems.init_state(
            cfg, admm, self.a_tilde, self.z0, jax.random.key(seed))

        self._step = jax.jit(partial(
            subproblems.admm_iteration, cfg, admm))
        self._lagr = jax.jit(partial(
            subproblems.lagrangian_value, cfg, admm))

        @jax.jit
        def _metrics(state: subproblems.ADMMState):
            logits = gcn.forward(cfg, self.a_tilde, self.z0,
                                 state.weights)[-1]
            z_pen = state.zs[-2] if cfg.num_layers >= 2 else self.z0
            res = state.zs[-1] - self.a_tilde @ z_pen @ state.weights[-1]
            return (gcn.accuracy(logits, self.labels, self.train_mask),
                    gcn.accuracy(logits, self.labels, self.test_mask),
                    jnp.linalg.norm(res))

        self._metrics = _metrics

    def step(self) -> None:
        self.state = self._step(self.a_tilde, self.z0, self.labels,
                                self.train_mask, self.state)

    def train(self, epochs: int, log_every: int = 1,
              verbose: bool = False) -> TrainLog:
        log = TrainLog()
        for epoch in range(epochs):
            t0 = time.perf_counter()
            self.step()
            jax.block_until_ready(self.state.zs[-1])
            dt = time.perf_counter() - t0
            if epoch % log_every == 0 or epoch == epochs - 1:
                tr, te, res = self._metrics(self.state)
                lag = self._lagr(self.a_tilde, self.z0, self.labels,
                                 self.train_mask, self.state)
                log.epoch.append(epoch)
                log.train_acc.append(float(tr))
                log.test_acc.append(float(te))
                log.lagrangian.append(float(lag))
                log.residual.append(float(res))
                log.epoch_time_s.append(dt)
                if verbose:
                    print(f"[serial-admm] epoch {epoch:3d} train {tr:.3f} "
                          f"test {te:.3f} lagr {lag:.4f} res {res:.3e} "
                          f"({dt*1e3:.1f} ms)")
        return log


# ---------------------------------------------------------------------------
# SGD-family baselines (paper §4.2 comparison methods)
# ---------------------------------------------------------------------------

class BaselineTrainer:
    """Backprop GCN training with the paper's comparison optimizers."""

    def __init__(self, cfg: gcn.GCNConfig, g: graph.Graph, optimizer: str,
                 lr: float, seed: int = 0):
        from repro.optim import optimizers
        self.cfg, self.graph = cfg, g
        self.a_tilde = jnp.asarray(
            graph.normalized_adjacency(g.num_nodes, g.edges))
        self.z0 = jnp.asarray(g.features)
        self.labels = jnp.asarray(g.labels)
        self.train_mask = jnp.asarray(g.train_mask, dtype=jnp.float32)
        self.test_mask = jnp.asarray(g.test_mask, dtype=jnp.float32)
        self.weights = gcn.init_weights(cfg, jax.random.key(seed))
        self.opt = optimizers.make(optimizer, lr)
        self.opt_state = self.opt.init(self.weights)

        @jax.jit
        def _step(weights, opt_state):
            loss, grads = jax.value_and_grad(
                lambda ws: gcn.loss_fn(cfg, self.a_tilde, self.z0, ws,
                                       self.labels, self.train_mask))(weights)
            updates, opt_state = self.opt.update(grads, opt_state, weights)
            weights = jax.tree.map(lambda w, u: w + u, weights, updates)
            return weights, opt_state, loss

        @jax.jit
        def _metrics(weights):
            logits = gcn.forward(cfg, self.a_tilde, self.z0, weights)[-1]
            return (gcn.accuracy(logits, self.labels, self.train_mask),
                    gcn.accuracy(logits, self.labels, self.test_mask))

        self._step, self._metrics = _step, _metrics

    def train(self, epochs: int, verbose: bool = False) -> TrainLog:
        log = TrainLog()
        for epoch in range(epochs):
            t0 = time.perf_counter()
            self.weights, self.opt_state, loss = self._step(
                self.weights, self.opt_state)
            jax.block_until_ready(self.weights[-1])
            dt = time.perf_counter() - t0
            tr, te = self._metrics(self.weights)
            log.epoch.append(epoch)
            log.train_acc.append(float(tr))
            log.test_acc.append(float(te))
            log.lagrangian.append(float(loss))
            log.residual.append(0.0)
            log.epoch_time_s.append(dt)
            if verbose:
                print(f"[{'baseline'}] epoch {epoch:3d} loss {loss:.4f} "
                      f"train {tr:.3f} test {te:.3f}")
        return log
