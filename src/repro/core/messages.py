"""First/second-order community messages (paper Appendix A, eq. 4).

In the paper, community ``m`` needs, for its ``Z_{l,m}`` subproblem:

  p_{l,r→m}  = Ã_{m,r} Z_{l,r} W_{l+1}                    (first order)
  s_{l,r→m}  = [Z_{l+1,r},  Σ_{r'∈N_r∪{r}\\{m}} p_{l,r'→r}]  (second order)

and eq. (4) shows the second-order payload is assembled by community r from
its *received* first-order messages — no second-hop communication.

On a TPU mesh the agents are shards on the ``comm`` axis.  The quantity each
community relays is its full first-order aggregate

  q_{l,r} = Σ_{r'∈N_r∪{r}} p_{l,r'→r} = (Σ_{r'} Ã_{r,r'} Z_{l,r'}) W_{l+1}

from which the receiver reconstructs the paper's s-message by subtracting its
own contribution:  s²_{l,r→m} = q_{l,r} − Ã_{r,m} Z_{l,m} W_{l+1}  (using
Ã_{r,m} = Ã_{m,r}ᵀ, Ã symmetric).  This file provides those helpers; the
shard_map trainer in ``parallel.py`` uses them, and tests assert equality
with the paper's literal per-neighbour message formulas.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def row_aggregate(a_row: Array, z_all: Array,
                  mask: Array | None = None) -> Array:
    """Σ_{r∈N_m} Ã_{m,r} Z_r — community m's first-order aggregation.

    a_row: (M, n_pad, n_pad) — m's row of Ã blocks (Ã_{m,r} for all r)
    z_all: (M, n_pad, C)     — all communities' Z (gathered)
    mask:  optional (M,) neighbour row; absent blocks contribute nothing
           (the blocks are zero anyway — the mask makes the paper's
           r ∈ N_m ∪ {m} restriction explicit and lets sparse backends skip)
    returns (n_pad, C)
    """
    if mask is not None:
        a_row = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ic", a_row, z_all)


def first_order_messages(a_row: Array, z_all: Array, w_next: Array,
                         mask: Array | None = None) -> Array:
    """Stacked p_{l,r→m} for all r: (M, n_pad, C_next).  p[r] = Ã_{m,r} Z_r W."""
    if mask is not None:
        a_row = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ric", a_row, z_all) @ w_next


def relay_aggregate(a_row: Array, z_all: Array, w_next: Array,
                    mask: Array | None = None) -> Array:
    """q_{l,m} = (Σ_r Ã_{m,r} Z_r) W_{l+1} — the payload community m relays."""
    return row_aggregate(a_row, z_all, mask) @ w_next


def gather_bytes(neighbor_mask, n_pad: int, feature_dims: Sequence[int],
                 itemsize: int = 4) -> dict:
    """Collective bytes per ADMM iteration: full all-gather vs the
    neighbour-only volume the paper's topology actually needs.

    Every iteration gathers one (M, n_pad, C) payload per entry of
    ``feature_dims`` (the Z_l layers, U, and the relay aggregates q).  The
    full all-gather moves M payload rows to every agent; neighbour-aware
    exchange moves only the rows r ∈ N_m ∪ {m}, i.e. nnz(neighbor_mask)
    row-payloads in total instead of M².
    """
    nbr = np.asarray(neighbor_mask)
    m = nbr.shape[0]
    nnz = int(nbr.sum())
    per_c = n_pad * itemsize
    full = sum(m * m * c * per_c for c in feature_dims)
    needed = sum(nnz * c * per_c for c in feature_dims)
    return {"full_bytes": full, "needed_bytes": needed,
            "nnz_blocks": nnz, "dense_blocks": m * m,
            "savings_ratio": 1.0 - (needed / full if full else 0.0)}


def adjacency_bytes(neighbor_mask, n_pad: int, itemsize: int = 4) -> dict:
    """Device-resident adjacency bytes per representation.

    ``dense_bytes`` is the replicated-layout block tensor the parallel
    trainer shards row-wise in dense mode (M² blocks in total across the
    mesh); ``ell_bytes`` is the block-compressed (ELL) payload the
    compressed trainer holds instead — M·max_deg blocks plus the int32
    index / float32 mask planes; ``csr_bytes`` is the tighter
    CSR-of-blocks bound (nnz blocks, host-side).  On power-law community
    graphs max_deg is ~constant in M, so ell_bytes grows ~linearly while
    dense_bytes grows quadratically.
    """
    nbr = np.asarray(neighbor_mask)
    m = nbr.shape[0]
    deg = nbr.sum(axis=1)
    max_deg = int(deg.max()) if m else 0
    nnz = int(nbr.sum())
    block = n_pad * n_pad * itemsize
    return {
        "dense_bytes": m * m * block,
        "ell_bytes": m * max_deg * (block + 4 + 4),
        "csr_bytes": nnz * block,
        "nnz_blocks": nnz,
        "max_deg": max_deg,
        "ell_ratio": (m * max_deg * (block + 8)) / (m * m * block)
        if m else 0.0,
    }


def second_order_from_relay(q_all: Array, a_row: Array, z_local: Array,
                            w_next: Array) -> Array:
    """s²_{l,r→m} for all r, reconstructed receiver-side (eq. 4).

    q_all:   (M, n_pad, C_next) — gathered relay aggregates q_{l,r}
    a_row:   (M, n_pad, n_pad)  — Ã_{m,r}; Ã_{r,m} = Ã_{m,r}ᵀ
    z_local: (n_pad, C_l)       — Z_{l,m}
    returns  (M, n_pad, C_next)
    """
    own_contrib = jnp.einsum("rnp,nc->rpc", a_row, z_local @ w_next)
    return q_all - own_contrib


def neighbor_preactivations(q_all: Array, a_row: Array, z_var: Array,
                            z_ref: Array, w_next: Array) -> Array:
    """Pre-activations of *every* community's next layer as a function of
    this community's variable ``z_var`` (with all other communities frozen
    at their k-th iterates, already baked into ``q_all`` via ``z_ref``):

        pre[r] = q_{l,r} + Ã_{r,m} (z_var − z_ref) W_{l+1}
               = s²_{l,r→m} + Ã_{r,m} z_var W_{l+1}

    For r ∉ N_m the Ã block is zero, so pre[r] is constant in z_var (those
    terms drop out of the gradient — the paper's neighbour-only coupling).
    """
    delta = (z_var - z_ref) @ w_next
    return q_all + jnp.einsum("rnp,nc->rpc", a_row, delta)
