"""First/second-order community messages (paper Appendix A, eq. 4).

In the paper, community ``m`` needs, for its ``Z_{l,m}`` subproblem:

  p_{l,r→m}  = Ã_{m,r} Z_{l,r} W_{l+1}                    (first order)
  s_{l,r→m}  = [Z_{l+1,r},  Σ_{r'∈N_r∪{r}\\{m}} p_{l,r'→r}]  (second order)

and eq. (4) shows the second-order payload is assembled by community r from
its *received* first-order messages — no second-hop communication.

On a TPU mesh the agents are shards on the ``comm`` axis.  The quantity each
community relays is its full first-order aggregate

  q_{l,r} = Σ_{r'∈N_r∪{r}} p_{l,r'→r} = (Σ_{r'} Ã_{r,r'} Z_{l,r'}) W_{l+1}

from which the receiver reconstructs the paper's s-message by subtracting its
own contribution:  s²_{l,r→m} = q_{l,r} − Ã_{r,m} Z_{l,m} W_{l+1}  (using
Ã_{r,m} = Ã_{m,r}ᵀ, Ã symmetric).  This file provides those helpers; the
shard_map trainer in ``parallel.py`` uses them, and tests assert equality
with the paper's literal per-neighbour message formulas.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def row_aggregate(a_row: Array, z_all: Array,
                  mask: Array | None = None) -> Array:
    """Σ_{r∈N_m} Ã_{m,r} Z_r — community m's first-order aggregation.

    a_row: (M, n_pad, n_pad) — m's row of Ã blocks (Ã_{m,r} for all r)
    z_all: (M, n_pad, C)     — all communities' Z (gathered)
    mask:  optional (M,) neighbour row; absent blocks contribute nothing
           (the blocks are zero anyway — the mask makes the paper's
           r ∈ N_m ∪ {m} restriction explicit and lets sparse backends skip)
    returns (n_pad, C)
    """
    if mask is not None:
        a_row = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ic", a_row, z_all)


def first_order_messages(a_row: Array, z_all: Array, w_next: Array,
                         mask: Array | None = None) -> Array:
    """Stacked p_{l,r→m} for all r: (M, n_pad, C_next).  p[r] = Ã_{m,r} Z_r W."""
    if mask is not None:
        a_row = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ric", a_row, z_all) @ w_next


def relay_aggregate(a_row: Array, z_all: Array, w_next: Array,
                    mask: Array | None = None) -> Array:
    """q_{l,m} = (Σ_r Ã_{m,r} Z_r) W_{l+1} — the payload community m relays."""
    return row_aggregate(a_row, z_all, mask) @ w_next


def gather_bytes(neighbor_mask, n_pad: int, feature_dims: Sequence[int],
                 itemsize: int = 4) -> dict:
    """Collective bytes per ADMM iteration: full all-gather vs the
    neighbour-only volume the paper's topology actually needs.

    Every iteration gathers one (M, n_pad, C) payload per entry of
    ``feature_dims`` (the Z_l layers, U, and the relay aggregates q).  The
    full all-gather moves M payload rows to every agent; neighbour-aware
    exchange moves only the rows r ∈ N_m ∪ {m}, i.e. nnz(neighbor_mask)
    row-payloads in total instead of M².
    """
    nbr = np.asarray(neighbor_mask)
    m = nbr.shape[0]
    nnz = int(nbr.sum())
    per_c = n_pad * itemsize
    full = sum(m * m * c * per_c for c in feature_dims)
    needed = sum(nnz * c * per_c for c in feature_dims)
    return {"full_bytes": full, "needed_bytes": needed,
            "nnz_blocks": nnz, "dense_blocks": m * m,
            "savings_ratio": 1.0 - (needed / full if full else 0.0)}


def adjacency_bytes(neighbor_mask, n_pad: int, itemsize: int = 4) -> dict:
    """Device-resident adjacency bytes per representation.

    ``dense_bytes`` is the replicated-layout block tensor the parallel
    trainer shards row-wise in dense mode (M² blocks in total across the
    mesh); ``ell_bytes`` is the block-compressed (ELL) payload the
    compressed trainer holds instead — M·max_deg blocks plus the int32
    index / float32 mask planes; ``csr_bytes`` is the tighter
    CSR-of-blocks bound (nnz blocks, host-side).  On power-law community
    graphs max_deg is ~constant in M, so ell_bytes grows ~linearly while
    dense_bytes grows quadratically.
    """
    nbr = np.asarray(neighbor_mask)
    m = nbr.shape[0]
    deg = nbr.sum(axis=1)
    max_deg = int(deg.max()) if m else 0
    nnz = int(nbr.sum())
    block = n_pad * n_pad * itemsize
    return {
        "dense_bytes": m * m * block,
        "ell_bytes": m * max_deg * (block + 4 + 4),
        "csr_bytes": nnz * block,
        "nnz_blocks": nnz,
        "max_deg": max_deg,
        "ell_ratio": (m * max_deg * (block + 8)) / (m * m * block)
        if m else 0.0,
    }


# ---------------------------------------------------------------------------
# neighbour-only point-to-point transport (ppermute round schedule)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangeRound:
    """One ``lax.ppermute`` round of the neighbour exchange.

    All shards run the round SPMD with the same ``(rows_pad, n_pad, C)``
    buffer shape; only the ``pairs`` actually transmit.  ``send_idx[s]``
    lists the *local lane* indices shard s packs (0-padded past its true
    row count); ``recv_slot[s]`` the receive-buffer slots the arriving rows
    scatter into, with pad positions pointing one past the buffer end so a
    ``mode='drop'`` scatter discards them.  For each pair both tables are
    written from the same ordered id list, so slot t on the source lines up
    with slot t on the destination.
    """
    offset: int                      # ring offset (dst - src) mod n_shards
    pairs: tuple[tuple[int, int], ...]
    rows_pad: int                    # padded rows per participating shard
    send_idx: np.ndarray             # (n_shards, rows_pad) int32 local lanes
    recv_slot: np.ndarray            # (n_shards, rows_pad) int32; r_pad=drop
    true_rows: int                   # Σ real rows over pairs (no padding)


@dataclasses.dataclass(frozen=True)
class NeighborExchange:
    """Static neighbour-only exchange plan over the community topology.

    Built host-side from ``neighbor_mask`` (equivalently the per-shard
    union of ``BlockCSR.ell_indices``): shard s must end up holding the
    payload rows of ``needed_ids[s]`` — its own k lanes (resident, no
    wire) plus every neighbour community of any of its lanes.  Messages
    (src shard → dst shard, list of community ids) are coloured into
    ``ppermute`` rounds by ring offset (sharding.partition.
    ring_round_coloring), so one exchange is ``len(rounds)`` static
    collective-permutes moving ``(rows_pad, n_pad, C)`` buffers — no
    ``(M, n_pad, C)`` gathered tensor is ever materialised.  Receive
    buffers are lane-major: ``(r_pad, n_pad, C)`` with each shard's own
    lanes and neighbour rows at the slots ``localize_indices`` remaps the
    ELL indices onto.
    """
    n_shards: int
    lanes_per_shard: int
    n_pad: int
    r_pad: int                       # receive-buffer rows (max over shards)
    needed_ids: tuple[tuple[int, ...], ...]   # per shard, slot -> global id
    own_slots: np.ndarray            # (n_shards, k) int32
    rounds: tuple[ExchangeRound, ...]

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    def slot_of(self, shard: int) -> dict[int, int]:
        """global community id -> receive-buffer slot on ``shard``."""
        return {int(r): i for i, r in enumerate(self.needed_ids[shard])}

    def localize_indices(self, ell_indices, ell_mask) -> np.ndarray:
        """Remap global ELL neighbour ids to receive-buffer slots.

        ``ell_indices``: (M, max_deg) global community ids (community-major
        rows, as BlockCSR stores them).  Row m belongs to shard m // k;
        every masked-in id is in that shard's needed set by construction.
        Masked-out (padding) entries map to slot 0 — they are multiplied by
        the zero mask by every consumer, any in-range slot is fine.
        """
        idx = np.asarray(ell_indices)
        msk = np.asarray(ell_mask) > 0
        k = self.lanes_per_shard
        slot_tables = [self.slot_of(s) for s in range(self.n_shards)]
        out = np.zeros_like(idx, dtype=np.int32)
        for m in range(idx.shape[0]):
            slots = slot_tables[m // k]
            for d in np.flatnonzero(msk[m]):
                out[m, d] = slots[int(idx[m, d])]
        return out


def build_neighbor_exchange(neighbor_mask, n_shards: int,
                            n_pad: int) -> NeighborExchange:
    """Construct the static round schedule for a community topology."""
    from repro.core.graph import shard_neighbor_graph
    from repro.sharding.partition import ring_round_coloring

    nbr = np.asarray(neighbor_mask, bool)
    m = nbr.shape[0]
    needed, _ = shard_neighbor_graph(nbr, n_shards)
    k = m // n_shards
    r_pad = max(len(ids) for ids in needed)
    slot_of = [{int(r): i for i, r in enumerate(ids)} for ids in needed]

    own_slots = np.zeros((n_shards, k), dtype=np.int32)
    for s in range(n_shards):
        for i in range(k):
            own_slots[s, i] = slot_of[s][s * k + i]

    # messages grouped by ring offset; ids kept sorted per (src, dst) pair
    msgs: dict[tuple[int, int], list[int]] = {}
    for dst in range(n_shards):
        for r in needed[dst]:
            src = int(r) // k
            if src != dst:
                msgs.setdefault((src, dst), []).append(int(r))
    colored = ring_round_coloring(msgs.keys(), n_shards)

    rounds = []
    for offset, pairs in colored.items():
        rows_pad = max(len(msgs[p]) for p in pairs)
        send_idx = np.zeros((n_shards, rows_pad), dtype=np.int32)
        recv_slot = np.full((n_shards, rows_pad), r_pad, dtype=np.int32)
        for src, dst in pairs:
            ids = msgs[(src, dst)]
            for t, r in enumerate(ids):
                send_idx[src, t] = r - src * k
                recv_slot[dst, t] = slot_of[dst][r]
        rounds.append(ExchangeRound(
            offset=offset, pairs=tuple(pairs), rows_pad=rows_pad,
            send_idx=send_idx, recv_slot=recv_slot,
            true_rows=sum(len(msgs[p]) for p in pairs)))

    return NeighborExchange(
        n_shards=n_shards, lanes_per_shard=k, n_pad=n_pad, r_pad=r_pad,
        needed_ids=tuple(tuple(int(r) for r in ids) for ids in needed),
        own_slots=own_slots, rounds=tuple(rounds))


def bf16_wire(collective, payload: Array) -> Array:
    """Run ``collective`` on a bf16-compressed payload (half the wire
    bytes) and restore the operand dtype.  The bf16 value travels bitcast
    as uint16 — a plain convert would be hoisted back to f32 by XLA's
    convert-mover, silently undoing the compression (§Perf log).  Both
    transports (all-gather and the p2p rounds) share this wrapper so the
    compression trick can only evolve in one place.
    """
    dt = payload.dtype
    if dt != jnp.float32:
        return collective(payload)
    wire = jax.lax.bitcast_convert_type(
        payload.astype(jnp.bfloat16), jnp.uint16)
    wire = collective(wire)
    return jax.lax.bitcast_convert_type(wire, jnp.bfloat16).astype(dt)


def exchange_neighbors(plan: NeighborExchange, x_loc: Array, axis: str,
                       comm_bf16: bool = False) -> Array:
    """Run the plan inside ``shard_map``: (k, n, C) local -> (r_pad, n, C).

    The returned buffer holds exactly the payload rows this shard's
    subproblems read (own lanes placed locally, neighbour rows arriving via
    the scheduled ``ppermute`` rounds) — the consumers index it through the
    ``localize_indices`` slot mapping.  With ``comm_bf16`` each round's
    payload travels bf16 (``bf16_wire``).  Note: only rows that actually
    cross the wire are compressed — a shard's own resident rows stay at
    full precision (strictly better numerics than the all-gather
    transport, which roundtrips every row; the transports are therefore
    bit-comparable oracles only at f32).
    """
    if plan.n_shards == 1:
        # the single shard hosts every community: slots are the identity
        # permutation and nothing hits the wire — returning the local
        # payload keeps the program bit-identical to the all-gather path
        return x_loc
    sid = jax.lax.axis_index(axis)
    dt = x_loc.dtype
    buf = jnp.zeros((plan.r_pad,) + x_loc.shape[1:], dt)
    buf = buf.at[jnp.asarray(plan.own_slots)[sid]].set(x_loc)
    for rnd in plan.rounds:
        payload = x_loc[jnp.asarray(rnd.send_idx)[sid]]
        permute = partial(jax.lax.ppermute, axis_name=axis,
                          perm=list(rnd.pairs))
        payload = bf16_wire(permute, payload) if comm_bf16 \
            else permute(payload)
        buf = buf.at[jnp.asarray(rnd.recv_slot)[sid]].set(payload,
                                                          mode="drop")
    return buf


def exchange_bytes(plan: NeighborExchange, feature_dims: Sequence[int],
                   itemsize: int = 4) -> dict:
    """Scheduled wire volume of the p2p transport per ADMM iteration.

    ``wire_bytes`` is what the ``ppermute`` rounds actually move: per round,
    every participating pair transmits the round's padded ``rows_pad`` rows
    (shards outside the round's partial permutation move nothing).
    ``p2p_needed_bytes`` counts only the true (unpadded) rows, so
    ``wire_bytes == p2p_needed_bytes + padding_bytes`` exactly — the
    invariant ``verify_transport_bytes`` enforces against the mask-derived
    ``gather_bytes`` accounting.
    """
    wire_rows = sum(len(r.pairs) * r.rows_pad for r in plan.rounds)
    true_rows = sum(r.true_rows for r in plan.rounds)
    per_c = plan.n_pad * itemsize
    wire = sum(wire_rows * c * per_c for c in feature_dims)
    needed = sum(true_rows * c * per_c for c in feature_dims)
    return {"wire_bytes": wire, "p2p_needed_bytes": needed,
            "padding_bytes": wire - needed, "wire_rows": wire_rows,
            "true_rows": true_rows, "num_rounds": plan.num_rounds,
            "r_pad": plan.r_pad,
            "lanes_per_shard": plan.lanes_per_shard}


def verify_transport_bytes(stats: dict) -> dict:
    """Invariant check tying the p2p schedule to the mask-derived stats.

    Hard invariants (raise — true by construction, a violation means the
    schedule or accounting is broken): (a) the transport never moves more
    than the all-gather it replaces, (b) wire == true scheduled rows +
    round padding, (c) the true rows stay within the block-level
    ``needed_bytes`` the masks record (per-shard deduplication only
    shrinks them).

    ``wire_bytes <= needed_bytes`` *including* padding additionally holds
    whenever each shard hosts one community (k=1: every round row is a
    real row, zero padding) — the benchmark sweeps and CI guards
    (benchmarks/check_bench.py) run in that regime and assert it strictly.
    On multi-lane shards round padding may legitimately exceed the mask
    slack on skewed topologies, so there it is recorded as
    ``wire_within_needed`` rather than raised — the schedule is still
    correct and still bounded by the all-gather volume.
    """
    wire = stats["wire_bytes"]
    if wire > stats["full_bytes"]:
        raise ValueError(
            f"p2p transport moves more than all-gather: wire={wire} > "
            f"full={stats['full_bytes']}")
    if wire != stats["p2p_needed_bytes"] + stats["padding_bytes"]:
        raise ValueError(
            f"wire accounting inconsistent: {wire} != "
            f"{stats['p2p_needed_bytes']} + {stats['padding_bytes']}")
    if stats["p2p_needed_bytes"] > stats["needed_bytes"]:
        raise ValueError(
            f"scheduled rows exceed the mask-derived needed volume: "
            f"{stats['p2p_needed_bytes']} > {stats['needed_bytes']}")
    stats["wire_within_needed"] = wire <= stats["needed_bytes"]
    if stats.get("lanes_per_shard") == 1 and not stats["wire_within_needed"]:
        raise ValueError(
            f"k=1 schedule has padding ({wire} > {stats['needed_bytes']}) "
            f"— impossible by construction, accounting is broken")
    return stats


def second_order_from_relay(q_all: Array, a_row: Array, z_local: Array,
                            w_next: Array) -> Array:
    """s²_{l,r→m} for all r, reconstructed receiver-side (eq. 4).

    q_all:   (M, n_pad, C_next) — gathered relay aggregates q_{l,r}
    a_row:   (M, n_pad, n_pad)  — Ã_{m,r}; Ã_{r,m} = Ã_{m,r}ᵀ
    z_local: (n_pad, C_l)       — Z_{l,m}
    returns  (M, n_pad, C_next)
    """
    own_contrib = jnp.einsum("rnp,nc->rpc", a_row, z_local @ w_next)
    return q_all - own_contrib


def neighbor_preactivations(q_all: Array, a_row: Array, z_var: Array,
                            z_ref: Array, w_next: Array) -> Array:
    """Pre-activations of *every* community's next layer as a function of
    this community's variable ``z_var`` (with all other communities frozen
    at their k-th iterates, already baked into ``q_all`` via ``z_ref``):

        pre[r] = q_{l,r} + Ã_{r,m} (z_var − z_ref) W_{l+1}
               = s²_{l,r→m} + Ã_{r,m} z_var W_{l+1}

    For r ∉ N_m the Ã block is zero, so pre[r] is constant in z_var (those
    terms drop out of the gradient — the paper's neighbour-only coupling).
    """
    delta = (z_var - z_ref) @ w_next
    return q_all + jnp.einsum("rnp,nc->rpc", a_row, delta)
