"""First/second-order community messages (paper Appendix A, eq. 4).

In the paper, community ``m`` needs, for its ``Z_{l,m}`` subproblem:

  p_{l,r→m}  = Ã_{m,r} Z_{l,r} W_{l+1}                    (first order)
  s_{l,r→m}  = [Z_{l+1,r},  Σ_{r'∈N_r∪{r}\\{m}} p_{l,r'→r}]  (second order)

and eq. (4) shows the second-order payload is assembled by community r from
its *received* first-order messages — no second-hop communication.

On a TPU mesh the agents are shards on the ``comm`` axis.  The quantity each
community relays is its full first-order aggregate

  q_{l,r} = Σ_{r'∈N_r∪{r}} p_{l,r'→r} = (Σ_{r'} Ã_{r,r'} Z_{l,r'}) W_{l+1}

from which the receiver reconstructs the paper's s-message by subtracting its
own contribution:  s²_{l,r→m} = q_{l,r} − Ã_{r,m} Z_{l,m} W_{l+1}  (using
Ã_{r,m} = Ã_{m,r}ᵀ, Ã symmetric).  This file provides those helpers; the
shard_map trainer in ``parallel.py`` uses them, and tests assert equality
with the paper's literal per-neighbour message formulas.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def row_aggregate(a_row: Array, z_all: Array,
                  mask: Array | None = None) -> Array:
    """Σ_{r∈N_m} Ã_{m,r} Z_r — community m's first-order aggregation.

    a_row: (M, n_pad, n_pad) — m's row of Ã blocks (Ã_{m,r} for all r)
    z_all: (M, n_pad, C)     — all communities' Z (gathered)
    mask:  optional (M,) neighbour row; absent blocks contribute nothing
           (the blocks are zero anyway — the mask makes the paper's
           r ∈ N_m ∪ {m} restriction explicit and lets sparse backends skip)
    returns (n_pad, C)
    """
    if mask is not None:
        a_row = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ic", a_row, z_all)


def first_order_messages(a_row: Array, z_all: Array, w_next: Array,
                         mask: Array | None = None) -> Array:
    """Stacked p_{l,r→m} for all r: (M, n_pad, C_next).  p[r] = Ã_{m,r} Z_r W."""
    if mask is not None:
        a_row = a_row * mask[:, None, None].astype(a_row.dtype)
    return jnp.einsum("rip,rpc->ric", a_row, z_all) @ w_next


def relay_aggregate(a_row: Array, z_all: Array, w_next: Array,
                    mask: Array | None = None) -> Array:
    """q_{l,m} = (Σ_r Ã_{m,r} Z_r) W_{l+1} — the payload community m relays."""
    return row_aggregate(a_row, z_all, mask) @ w_next


def gather_bytes(neighbor_mask: np.ndarray, n_pad: int,
                 feature_dims: Sequence[int], itemsize: int = 4) -> dict:
    """Collective bytes per ADMM iteration: full all-gather vs the
    neighbour-only volume the paper's topology actually needs.

    Every iteration gathers one (M, n_pad, C) payload per entry of
    ``feature_dims`` (the Z_l layers, U, and the relay aggregates q).  The
    full all-gather moves M payload rows to every agent; neighbour-aware
    exchange moves only the rows r ∈ N_m ∪ {m}, i.e. nnz(neighbor_mask)
    row-payloads in total instead of M².
    """
    nbr = np.asarray(neighbor_mask)
    m = nbr.shape[0]
    nnz = int(nbr.sum())
    per_c = n_pad * itemsize
    full = sum(m * m * c * per_c for c in feature_dims)
    needed = sum(nnz * c * per_c for c in feature_dims)
    return {"full_bytes": full, "needed_bytes": needed,
            "nnz_blocks": nnz, "dense_blocks": m * m,
            "savings_ratio": 1.0 - (needed / full if full else 0.0)}


def adjacency_bytes(neighbor_mask: np.ndarray, n_pad: int,
                    itemsize: int = 4) -> dict:
    """Device-resident adjacency bytes per representation.

    ``dense_bytes`` is the replicated-layout block tensor the parallel
    trainer shards row-wise in dense mode (M² blocks in total across the
    mesh); ``ell_bytes`` is the block-compressed (ELL) payload the
    compressed trainer holds instead — M·max_deg blocks plus the int32
    index / float32 mask planes; ``csr_bytes`` is the tighter
    CSR-of-blocks bound (nnz blocks, host-side).  ``itemsize`` is the ELL
    *block-store* element size (2 with ``adjacency_bf16``) — it scales
    only ``ell_bytes``; the dense and CSR baselines are always the f32
    tensors those representations actually are, so ``ell_ratio`` shows
    the bf16 win instead of silently halving the comparison point.  On
    power-law community graphs max_deg is ~constant in M, so ell_bytes
    grows ~linearly while dense_bytes grows quadratically.
    """
    nbr = np.asarray(neighbor_mask)
    m = nbr.shape[0]
    deg = nbr.sum(axis=1)
    max_deg = int(deg.max()) if m else 0
    nnz = int(nbr.sum())
    block = n_pad * n_pad
    dense = m * m * block * 4
    ell = m * max_deg * (block * itemsize + 4 + 4)
    return {
        "dense_bytes": dense,
        "ell_bytes": ell,
        "csr_bytes": nnz * block * 4,
        "nnz_blocks": nnz,
        "max_deg": max_deg,
        "block_itemsize": itemsize,
        "ell_ratio": ell / dense if m else 0.0,
    }


def pad_stats(neighbor_mask: np.ndarray, sizes: np.ndarray,
              row_counts: np.ndarray, n_pad: int,
              feature_dims: Sequence[int], itemsize: int = 4) -> dict:
    """Residual-padding accounting of a (possibly ragged) layout.

    ``sizes`` are the true community row counts, ``row_counts`` the padded
    counts actually processed (None = the global ``n_pad`` everywhere).
    Per ADMM iteration (one payload per entry of ``feature_dims``, the same
    convention as ``gather_bytes``):

      * ``pad_rows`` / ``pad_bytes`` — payload rows (bytes) that carry
        padding, Σ_m (row_counts[m] − sizes[m]);
      * ``pad_flops`` — MXU work the block aggregation spends on pad
        rows/cols: Σ_{(m,r)∈nbr} 2·C·(rc_m·rc_r − s_m·s_r), i.e. processed
        minus irreducible true-row FLOPs (the ELL kernel's row-count guards
        skip pad work at tile granularity; this is the row-exact bound).

    Bucketed row_counts shrink both against the global-pad baseline on any
    size-skewed partition — the drop CI guards via BENCH_speedup.json's
    ``m32_ragged`` section.
    """
    nbr = np.asarray(neighbor_mask, bool)
    s = np.asarray(sizes, dtype=np.int64)
    rc = np.full(s.shape, n_pad, dtype=np.int64) if row_counts is None \
        else np.asarray(row_counts, dtype=np.int64)
    if (rc < s).any():
        raise ValueError("row_counts below true community sizes")
    total_c = int(np.sum(list(feature_dims)))
    pad_rows = int((rc - s).sum())
    processed = float(np.outer(rc, rc)[nbr].sum())
    true = float(np.outer(s, s)[nbr].sum())
    agg_flops = 2.0 * total_c * processed
    pad_flops = 2.0 * total_c * (processed - true)
    return {
        "pad_rows": pad_rows,
        "pad_bytes": pad_rows * total_c * itemsize,
        "pad_flops": pad_flops,
        "agg_flops": agg_flops,
        "pad_flop_frac": pad_flops / agg_flops if agg_flops else 0.0,
        "padded_rows_total": int(rc.sum()),
        "true_rows_total": int(s.sum()),
    }


# ---------------------------------------------------------------------------
# neighbour-only point-to-point transport (ppermute round schedule)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangeRound:
    """One ``lax.ppermute`` round of the neighbour exchange.

    All shards run the round SPMD with the same ``(rows_pad, C)`` buffer
    shape; only the ``pairs`` actually transmit.  Rows are *node* rows: a
    community contributes only its true ``sizes[r]`` rows (row-exact), or
    all ``n_pad`` rows when the plan was built without sizes (the
    global-pad / whole-block behaviour).  ``send_idx[s]`` lists the flat
    local node-row indices (into the (k·n_pad, C)-flattened local payload)
    shard s packs, 0-padded past its true row count; ``recv_slot[s]`` the
    flat receive-buffer rows (into (r_pad·n_pad, C)) the arriving rows
    scatter into, with pad positions pointing one past the buffer end so a
    ``mode='drop'`` scatter discards them.  For each pair both tables are
    written from the same ordered row list, so row t on the source lines up
    with row t on the destination.
    """
    offset: int                      # colour id of the round (edge colouring)
    pairs: tuple[tuple[int, int], ...]
    rows_pad: int                    # padded node rows per participating shard
    send_idx: np.ndarray             # (n_shards, rows_pad) int32 flat rows
    recv_slot: np.ndarray            # (n_shards, rows_pad) int32; OOB=drop
    true_rows: int                   # Σ real node rows over pairs (no padding)
    # packed-plane twins (plans built with row_counts): rows into the local
    # (plane_rows, C) state plane / the (recv_plane_rows, C) receive plane
    send_rows_packed: "np.ndarray | None" = None
    recv_rows_packed: "np.ndarray | None" = None


@dataclasses.dataclass(frozen=True)
class NeighborExchange:
    """Static neighbour-only exchange plan over the community topology.

    Built host-side from ``neighbor_mask`` (equivalently the per-shard
    union of ``BlockCSR.ell_indices``): shard s must end up holding the
    payload rows of ``needed_ids[s]`` — its own k lanes (resident, no
    wire) plus every neighbour community of any of its lanes.  Messages
    (src shard → dst shard, list of community ids) are coloured into
    ``ppermute`` rounds by ring offset (sharding.partition.
    ring_round_coloring), so one exchange is ``len(rounds)`` static
    collective-permutes moving ``(rows_pad, C)`` node-row buffers — no
    ``(M, n_pad, C)`` gathered tensor is ever materialised.  Receive
    buffers are lane-major: ``(r_pad, n_pad, C)`` with each shard's own
    lanes and neighbour rows at the slots ``localize_indices`` remaps the
    ELL indices onto.

    Row-exact mode (``sizes`` given, ``row_exact=True``): each wired
    community contributes only its true node rows, so on a size-skewed
    partition the wire volume tracks Σ sizes over cross-shard messages
    instead of (#messages)·n_pad — the pad rows never leave the device.
    Receive-buffer rows past a community's size simply stay zero, exactly
    the value the whole-block transport would have delivered.
    """
    n_shards: int
    lanes_per_shard: int
    n_pad: int
    r_pad: int                       # receive-buffer rows (max over shards)
    needed_ids: tuple[tuple[int, ...], ...]   # per shard, slot -> global id
    own_slots: np.ndarray            # (n_shards, k) int32
    rounds: tuple[ExchangeRound, ...]
    sizes: tuple[int, ...] = ()      # per community wired rows (n_pad if not
    row_exact: bool = False          # row-exact)
    # packed-plane metadata (plans built with row_counts): the send side is
    # the shard's (plane_rows, C) state plane (PackedDeviceLayout); the
    # receive side a (recv_plane_rows, C) plane with slot j's community at
    # recv_offsets[s, j] for row_counts[gid] bucket rows
    row_counts: tuple[int, ...] = ()
    plane_rows: int = 0
    recv_plane_rows: int = 0
    local_offsets: "np.ndarray | None" = None   # (M,) row in the home plane
    recv_offsets: "np.ndarray | None" = None    # (n_shards, r_pad); OOB=unused
    own_copy_rows: "np.ndarray | None" = None   # (n_shards, recv_plane_rows)
    recv_unpack_rows: "np.ndarray | None" = None  # (n_shards, r_pad·n_pad)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    @property
    def packed(self) -> bool:
        """True when the plan carries packed-plane routing tables."""
        return self.recv_offsets is not None

    def slot_of(self, shard: int) -> dict[int, int]:
        """global community id -> receive-buffer slot on ``shard``."""
        return {int(r): i for i, r in enumerate(self.needed_ids[shard])}

    def localize_indices(self, ell_indices: np.ndarray,
                         ell_mask: np.ndarray) -> np.ndarray:
        """Remap global ELL neighbour ids to receive-buffer slots.

        ``ell_indices``: (M, max_deg) global community ids (community-major
        rows, as BlockCSR stores them).  Row m belongs to shard m // k;
        every masked-in id is in that shard's needed set by construction.
        Masked-out (padding) entries map to slot 0 — they are multiplied by
        the zero mask by every consumer, any in-range slot is fine.
        """
        idx = np.asarray(ell_indices)
        msk = np.asarray(ell_mask) > 0
        k = self.lanes_per_shard
        slot_tables = [self.slot_of(s) for s in range(self.n_shards)]
        out = np.zeros_like(idx, dtype=np.int32)
        for m in range(idx.shape[0]):
            slots = slot_tables[m // k]
            for d in np.flatnonzero(msk[m]):
                out[m, d] = slots[int(idx[m, d])]
        return out

    def localized_offsets(self, ell_indices: np.ndarray,
                          ell_mask: np.ndarray) -> np.ndarray:
        """Receive-plane *row offsets* of every ELL neighbour slot.

        The packed twin of ``localize_indices``: instead of a buffer slot
        (a multiple-of-``n_pad`` stride), each masked-in (m, d) entry maps
        to the first receive-plane row of its neighbour's bucket —
        ``recv_offsets[shard(m), slot]`` — which is what the offset-indexed
        ELL kernel scalar-prefetches to steer its Z DMA.  Masked-out
        entries map to row 0 (in range, multiplied away by the mask).
        """
        if self.recv_offsets is None:
            raise ValueError("plan built without row_counts has no packed "
                             "receive plane — pass row_counts to "
                             "build_neighbor_exchange")
        loc = self.localize_indices(ell_indices, ell_mask)
        msk = np.asarray(ell_mask) > 0
        k = self.lanes_per_shard
        out = np.zeros_like(loc, dtype=np.int32)
        for m in range(loc.shape[0]):
            offs = self.recv_offsets[m // k]
            for d in np.flatnonzero(msk[m]):
                out[m, d] = offs[loc[m, d]]
        return out


def plane_read_offsets(ell_indices: np.ndarray, ell_mask: np.ndarray,
                       local_offsets: np.ndarray) -> np.ndarray:
    """Resident-plane row offsets of every ELL neighbour slot.

    The single-plane twin of ``NeighborExchange.localized_offsets``: when
    every community is resident on one packed plane (serving, or a 1-shard
    mesh) there is no receive buffer to remap through — each masked-in
    (m, d) slot reads its neighbour's bucket starting at
    ``local_offsets[ell_indices[m, d]]``.  Masked-out slots map to row 0
    (in range; multiplied away by the mask).  This is the halo-read table
    the serving engine scalar-prefetches into the packed ELL kernel.
    """
    idx = np.asarray(ell_indices)
    msk = np.asarray(ell_mask) > 0
    offs = np.asarray(local_offsets, dtype=np.int32)
    return np.where(msk, offs[idx], 0).astype(np.int32)


def self_slot_mask(ell_indices: np.ndarray, ell_mask: np.ndarray
                   ) -> np.ndarray:
    """(M, max_deg) float32 marking each ELL row's *self* (diagonal) slot.

    ``ell_mask - self_slot_mask`` is then the cross-community (halo) mask:
    the serving engine aggregates the two halves separately so the halo
    part — the only part that depends on other communities — can be cached
    and invalidated on its own (kernels.ops.community_halo_spmm).
    """
    idx = np.asarray(ell_indices)
    msk = np.asarray(ell_mask) > 0
    rows = np.arange(idx.shape[0])[:, None]
    return ((idx == rows) & msk).astype(np.float32)


def build_neighbor_exchange(neighbor_mask: np.ndarray, n_shards: int,
                            n_pad: int,
                            sizes: np.ndarray | None = None,
                            row_counts: np.ndarray | None = None
                            ) -> NeighborExchange:
    """Construct the static round schedule for a community topology.

    ``sizes`` (optional, (M,) true rows per community) switches the plan to
    row-exact packing: each cross-shard message carries only the true node
    rows of its communities.  Without it every community wires all
    ``n_pad`` rows — byte-identical to the historic whole-block schedule.

    ``row_counts`` (optional, (M,) bucket rows per community,
    ``CommunityLayout.eff_row_counts``) additionally equips the plan with
    *packed-plane* routing tables: send rows index the shard's packed
    Σ-bucket-rows state plane (``PackedDeviceLayout``) and receive rows a
    packed receive plane with one bucket per needed slot, so a packed
    trainer never materialises a strided ``(r_pad, n_pad, C)`` buffer on
    the wire path.  The wired rows themselves are unchanged — packed and
    strided plans schedule byte-identical rounds.
    """
    from repro.core.graph import shard_neighbor_graph
    from repro.sharding.partition import ring_round_coloring

    nbr = np.asarray(neighbor_mask, bool)
    m = nbr.shape[0]
    needed, _ = shard_neighbor_graph(nbr, n_shards)
    k = m // n_shards
    row_exact = sizes is not None
    wired = np.full(m, n_pad, dtype=np.int64) if sizes is None \
        else np.asarray(sizes, dtype=np.int64)
    if wired.shape != (m,) or (wired < 0).any() or (wired > n_pad).any():
        raise ValueError(f"sizes must be (M,) in [0, n_pad={n_pad}]")
    r_pad = max(len(ids) for ids in needed)
    slot_of = [{int(r): i for i, r in enumerate(ids)} for ids in needed]

    packed = row_counts is not None
    if packed:
        rc = np.asarray(row_counts, dtype=np.int64)
        if rc.shape != (m,) or (rc > n_pad).any() or (rc < wired).any():
            raise ValueError("row_counts must be (M,) in [wired rows, "
                             f"n_pad={n_pad}] — buckets cover what is wired")
        local_offsets = np.zeros(m, dtype=np.int32)
        for s in range(n_shards):
            local_offsets[s * k:(s + 1) * k] = np.concatenate(
                [[0], np.cumsum(rc[s * k:(s + 1) * k])[:-1]])
        plane_rows = max(int(rc.reshape(n_shards, k).sum(axis=1).max()), 8)
        recv_offsets = np.full((n_shards, r_pad), 0, dtype=np.int32)
        recv_rows = np.zeros(n_shards, dtype=np.int64)
        for s in range(n_shards):
            cnts = [int(rc[g]) for g in needed[s]]
            offs = np.concatenate([[0], np.cumsum(cnts)]).astype(np.int32)
            recv_offsets[s, :len(cnts)] = offs[:-1]
            recv_rows[s] = offs[-1]
        recv_plane_rows = max(int(recv_rows.max()), 8)
        # unused trailing slots point one past the plane (drop/fill)
        for s in range(n_shards):
            recv_offsets[s, len(needed[s]):] = recv_plane_rows
        own_copy_rows = np.full((n_shards, recv_plane_rows), plane_rows,
                                dtype=np.int32)
        recv_unpack = np.full((n_shards, r_pad * n_pad), recv_plane_rows,
                              dtype=np.int32)
        for s in range(n_shards):
            for slot, gid in enumerate(needed[s]):
                cnt = int(rc[gid])
                rows = np.arange(cnt)
                recv_unpack[s, slot * n_pad: slot * n_pad + cnt] = \
                    recv_offsets[s, slot] + rows
                if gid // k == s:           # resident lane: local plane copy
                    own_copy_rows[s, recv_offsets[s, slot]:
                                  recv_offsets[s, slot] + cnt] = \
                        local_offsets[gid] + rows
    else:
        rc = None
        local_offsets = recv_offsets = own_copy_rows = recv_unpack = None
        plane_rows = recv_plane_rows = 0

    own_slots = np.zeros((n_shards, k), dtype=np.int32)
    for s in range(n_shards):
        for i in range(k):
            own_slots[s, i] = slot_of[s][s * k + i]

    # messages grouped by ring offset; ids kept sorted per (src, dst) pair
    msgs: dict[tuple[int, int], list[int]] = {}
    for dst in range(n_shards):
        for r in needed[dst]:
            src = int(r) // k
            if src != dst:
                msgs.setdefault((src, dst), []).append(int(r))
    colored = ring_round_coloring(msgs.keys(), n_shards)

    def msg_rows(pair):                 # true node rows of one message
        return int(sum(wired[r] for r in msgs[pair]))

    rounds = []
    for offset, pairs in colored.items():
        # Row-exact plans may split a colour round into power-of-two
        # size-bucketed sub-rounds: every round's buffer pads to its
        # largest message, so letting a 10-row and a 500-row message share
        # a round would wire 490 pad rows — grouping pairs whose row
        # counts share a bucket bounds round padding by the bucket ratio
        # (< 2×) instead of the offset's largest message.  Each sub-round
        # is a subset of a partial permutation, hence still one.  The
        # split is taken only when it at least halves the round's
        # scheduled wire: each extra round is an extra collective launch
        # whose SPMD buffer every shard materialises, so on near-uniform
        # message sizes (where padding is small anyway) one round per
        # offset stays cheaper end-to-end.  Whole-block plans always keep
        # one round per offset (all messages are count·n_pad rows — the
        # historic schedule, byte-identical).
        grouped = [list(pairs)]
        if row_exact:
            groups: dict[int, list] = {}
            for p in pairs:
                rows = msg_rows(p)
                bucket = 1 << max(0, int(np.ceil(np.log2(max(1, rows)))))
                groups.setdefault(bucket, []).append(p)
            split = [grp for _, grp in sorted(groups.items())]
            plain_wire = len(pairs) * max(msg_rows(p) for p in pairs)
            split_wire = sum(len(g) * max(msg_rows(p) for p in g)
                             for g in split)
            if 2 * split_wire <= plain_wire:
                grouped = split
        for grp in grouped:
            rows_pad = max(msg_rows(p) for p in grp)
            if rows_pad == 0:
                continue                # all-empty messages: nothing to wire
            send_idx = np.zeros((n_shards, rows_pad), dtype=np.int32)
            recv_slot = np.full((n_shards, rows_pad), r_pad * n_pad,
                                dtype=np.int32)
            send_pk = np.zeros((n_shards, rows_pad), dtype=np.int32) \
                if packed else None
            recv_pk = np.full((n_shards, rows_pad), recv_plane_rows,
                              dtype=np.int32) if packed else None
            for src, dst in grp:
                t = 0
                for r in msgs[(src, dst)]:
                    rows = int(wired[r])
                    send_idx[src, t:t + rows] = \
                        (r - src * k) * n_pad + np.arange(rows)
                    recv_slot[dst, t:t + rows] = \
                        slot_of[dst][r] * n_pad + np.arange(rows)
                    if packed:
                        send_pk[src, t:t + rows] = \
                            local_offsets[r] + np.arange(rows)
                        recv_pk[dst, t:t + rows] = \
                            recv_offsets[dst, slot_of[dst][r]] \
                            + np.arange(rows)
                    t += rows
            rounds.append(ExchangeRound(
                offset=offset, pairs=tuple(grp), rows_pad=rows_pad,
                send_idx=send_idx, recv_slot=recv_slot,
                true_rows=sum(msg_rows(p) for p in grp),
                send_rows_packed=send_pk, recv_rows_packed=recv_pk))

    return NeighborExchange(
        n_shards=n_shards, lanes_per_shard=k, n_pad=n_pad, r_pad=r_pad,
        needed_ids=tuple(tuple(int(r) for r in ids) for ids in needed),
        own_slots=own_slots, rounds=tuple(rounds),
        sizes=tuple(int(v) for v in wired), row_exact=row_exact,
        row_counts=tuple(int(v) for v in rc) if packed else (),
        plane_rows=plane_rows, recv_plane_rows=recv_plane_rows,
        local_offsets=local_offsets, recv_offsets=recv_offsets,
        own_copy_rows=own_copy_rows, recv_unpack_rows=recv_unpack)


def restrict_exchange(plan: NeighborExchange,
                      sampled_shards) -> NeighborExchange:
    """Sampled-round sub-schedule: the plan restricted to the pairs a
    community minibatch actually reads.

    Under stochastic community minibatching only the *sampled* shards'
    subproblems run, so only they need to receive — a ppermute pair
    ``(src, dst)`` survives iff ``dst`` is sampled.  The source side is
    NOT filtered: an unsampled neighbour's (stale, exact) Z/U rows still
    feed every sampled consumer's coupling terms, so unsampled shards
    keep sending.  Unsampled edges — pairs into unsampled shards — carry
    zero wire: their rounds either shrink or vanish.

    Buffer geometry is untouched (``needed_ids``/slots/``r_pad``/packed
    plane tables), so ELL indices and offsets localized against the full
    plan stay valid on the sub-schedule; rows a dropped pair would have
    delivered simply stay zero, values an unsampled consumer never
    reads.  Kept rounds re-pad to their largest surviving message and
    all-dropped rounds disappear, so ``exchange_bytes`` on the sub-plan
    prices exactly the sampled wire.  Restricting to the full shard set
    returns ``plan`` itself — the compiled full-batch program is the
    batch_fraction=1.0 program, bit for bit.
    """
    sampled = frozenset(int(s) for s in sampled_shards)
    if not sampled:
        raise ValueError("sampled_shards must be non-empty")
    if not sampled <= set(range(plan.n_shards)):
        raise ValueError(f"sampled shards {sorted(sampled)} out of range "
                         f"for n_shards={plan.n_shards}")
    if len(sampled) == plan.n_shards:
        return plan
    limit = plan.r_pad * plan.n_pad
    rounds = []
    for rnd in plan.rounds:
        kept = tuple(p for p in rnd.pairs if p[1] in sampled)
        if not kept:
            continue
        # per-pair true rows: a round is a partial permutation, so each
        # destination receives exactly one message — its in-range
        # recv_slot entries count that message's rows
        rows_of = {p: int((rnd.recv_slot[p[1]] < limit).sum())
                   for p in kept}
        rows_pad = max(rows_of.values())
        if rows_pad == 0:
            continue
        rounds.append(ExchangeRound(
            offset=rnd.offset, pairs=kept, rows_pad=rows_pad,
            send_idx=rnd.send_idx[:, :rows_pad],
            recv_slot=rnd.recv_slot[:, :rows_pad],
            true_rows=sum(rows_of.values()),
            send_rows_packed=None if rnd.send_rows_packed is None
            else rnd.send_rows_packed[:, :rows_pad],
            recv_rows_packed=None if rnd.recv_rows_packed is None
            else rnd.recv_rows_packed[:, :rows_pad]))
    return dataclasses.replace(plan, rounds=tuple(rounds))


def bf16_wire(collective: Callable[[Array], Array],
              payload: Array) -> Array:
    """Run ``collective`` on a bf16-compressed payload (half the wire
    bytes) and restore the operand dtype.  The bf16 value travels bitcast
    as uint16 — a plain convert would be hoisted back to f32 by XLA's
    convert-mover, silently undoing the compression (§Perf log).  Both
    transports (all-gather and the p2p rounds) share this wrapper so the
    compression trick can only evolve in one place.
    """
    dt = payload.dtype
    if dt != jnp.float32:
        return collective(payload)
    wire = jax.lax.bitcast_convert_type(
        payload.astype(jnp.bfloat16), jnp.uint16)
    wire = collective(wire)
    return jax.lax.bitcast_convert_type(wire, jnp.bfloat16).astype(dt)


def exchange_neighbors(plan: NeighborExchange, x_loc: Array, axis: str,
                       comm_bf16: bool = False) -> Array:
    """Run the plan inside ``shard_map``: (k, n, C) local -> (r_pad, n, C).

    The returned buffer holds exactly the payload rows this shard's
    subproblems read (own lanes placed locally, neighbour rows arriving via
    the scheduled ``ppermute`` rounds) — the consumers index it through the
    ``localize_indices`` slot mapping.  With ``comm_bf16`` each round's
    payload travels bf16 (``bf16_wire``).  Note: only rows that actually
    cross the wire are compressed — a shard's own resident rows stay at
    full precision (strictly better numerics than the all-gather
    transport, which roundtrips every row; the transports are therefore
    bit-comparable oracles only at f32).
    """
    if plan.n_shards == 1:
        # the single shard hosts every community: slots are the identity
        # permutation and nothing hits the wire — returning the local
        # payload keeps the program bit-identical to the all-gather path
        return x_loc
    sid = jax.lax.axis_index(axis)
    dt = x_loc.dtype
    k, n = x_loc.shape[0], x_loc.shape[1]
    feat = x_loc.shape[2:]
    # node-row-flat views: send rows are gathered (and receive rows
    # scattered) at single-node granularity so row-exact plans wire only
    # the true rows of each community
    x_flat = x_loc.reshape((k * n,) + feat)
    buf = jnp.zeros((plan.r_pad * n,) + feat, dt)
    own = jnp.asarray(plan.own_slots)[sid]                    # (k,)
    own_flat = (own[:, None] * n + jnp.arange(n)[None, :]).reshape(-1)
    buf = buf.at[own_flat].set(x_flat)
    for rnd in plan.rounds:
        payload = x_flat[jnp.asarray(rnd.send_idx)[sid]]
        permute = partial(jax.lax.ppermute, axis_name=axis,
                          perm=list(rnd.pairs))
        payload = bf16_wire(permute, payload) if comm_bf16 \
            else permute(payload)
        buf = buf.at[jnp.asarray(rnd.recv_slot)[sid]].set(payload,
                                                          mode="drop")
    return buf.reshape((plan.r_pad, n) + feat)


def exchange_neighbors_packed(plan: NeighborExchange, x_plane: Array,
                              axis: str, comm_bf16: bool = False,
                              staged: bool = False):
    """Run the plan on the packed state plane inside ``shard_map``.

    ``x_plane``: (plane_rows, C) — this shard's packed Σ-bucket-rows
    state (``PackedDeviceLayout``).  Returns the packed receive plane
    ``(recv_plane_rows, C)``: slot j's bucket rows live at
    ``recv_offsets[s, j]``, own lanes copied locally, neighbour rows
    arriving through the same ppermute rounds (same pairs, same payload
    rows — byte-identical wire) as the strided ``exchange_neighbors``.

    With ``staged=True`` the *incremental* buffer states are returned as
    a list — ``[after own-copy, after round 0, ..., final]`` — so a
    consumer can start aggregating the slots a round has already
    delivered while later rounds are still on the wire (the
    double-buffered overlap schedule; see ``arrival_rounds``).
    """
    if plan.recv_offsets is None:
        raise ValueError("plan built without row_counts cannot route the "
                         "packed plane")
    if plan.n_shards == 1:
        # one shard hosts every community and the needed-ids slot order is
        # the lane order, so the receive plane IS the local plane
        return [x_plane] if staged else x_plane
    sid = jax.lax.axis_index(axis)
    own_tbl = jnp.asarray(plan.own_copy_rows)[sid]
    buf = jnp.take(x_plane, own_tbl, axis=0, mode="fill", fill_value=0)
    bufs = [buf]
    for rnd in plan.rounds:
        payload = x_plane[jnp.asarray(rnd.send_rows_packed)[sid]]
        permute = partial(jax.lax.ppermute, axis_name=axis,
                          perm=list(rnd.pairs))
        payload = bf16_wire(permute, payload) if comm_bf16 \
            else permute(payload)
        buf = buf.at[jnp.asarray(rnd.recv_rows_packed)[sid]].set(
            payload, mode="drop")
        bufs.append(buf)
    return bufs if staged else buf


def arrival_rounds(plan: NeighborExchange) -> np.ndarray:
    """(n_shards, r_pad) int32: index of the ppermute round that delivers
    each receive slot's payload; -1 for resident own lanes (available
    before any wire) and never-wired padding slots."""
    arr = np.full((plan.n_shards, plan.r_pad), -1, dtype=np.int32)
    limit = plan.r_pad * plan.n_pad
    for ri, rnd in enumerate(plan.rounds):
        for _, dst in rnd.pairs:
            rows = rnd.recv_slot[dst]
            slots = np.unique(rows[rows < limit] // plan.n_pad)
            arr[dst, slots] = ri
    return arr


def overlap_stats(plan: NeighborExchange, neighbor_mask: np.ndarray,
                  feature_dims: Sequence[int], itemsize: int = 4,
                  enabled: bool = False,
                  peak_flops: float | None = None,
                  ici_bw: float | None = None) -> dict:
    """Analytic exposed-vs-total wire time of the round schedule.

    Models the double-buffered overlap the staged exchange enables: while
    round r is on the wire, a shard can aggregate every ELL slot whose
    payload is already resident (own lanes before round 0, round r' < r
    arrivals after).  Per round, the exposed wire time is what the
    available aggregation work cannot hide:

        exposed_r = max(0, t_wire(r) − credit_r)

    with ``credit`` the pipelined budget of hideable compute (unspent
    credit carries forward; compute of slots arriving in the final round
    runs after the wire and hides nothing).  Wire time prices each
    round's per-pair payload over one ICI link; compute prices the
    row-exact block-aggregation FLOPs (2·rc_m·rc_src·ΣC per consumed ELL
    slot) at peak MXU throughput — both from ``repro.launch.mesh``, so
    the metric is a deterministic property of the schedule, not a
    wall-clock sample.  The worst shard's exposure is reported (SPMD
    rounds advance at the slowest participant).

    ``overlap_efficiency`` = hidden / total wire time ∈ [0, 1];
    ``exposed_wire_bytes`` = exposed seconds × link bandwidth is what the
    roofline prices instead of total wire bytes (``benchmarks/roofline``).
    """
    if peak_flops is None or ici_bw is None:
        from repro.launch.mesh import ICI_BW, PEAK_FLOPS
        peak_flops = PEAK_FLOPS if peak_flops is None else peak_flops
        ici_bw = ICI_BW if ici_bw is None else ici_bw
    nbr = np.asarray(neighbor_mask, bool)
    m = nbr.shape[0]
    k = plan.lanes_per_shard
    rc = np.asarray(plan.row_counts, dtype=np.int64) if plan.row_counts \
        else np.full(m, plan.n_pad, dtype=np.int64)
    total_c = int(np.sum(list(feature_dims)))
    n_gathers = len(list(feature_dims))
    arr = arrival_rounds(plan)
    t_wire = [r.rows_pad * total_c * itemsize / ici_bw for r in plan.rounds]
    total = float(sum(t_wire))

    # per-shard hideable compute per arrival group (seconds, all gathers)
    worst_exposed = 0.0
    for s in range(plan.n_shards):
        slot_gid = plan.needed_ids[s]
        group_flops = np.zeros(plan.num_rounds + 1)
        for lane in range(s * k, (s + 1) * k):
            for slot, gid in enumerate(slot_gid):
                if not nbr[lane, gid]:
                    continue
                g = int(arr[s, slot]) + 1          # own lanes -> group 0
                group_flops[g] += 2.0 * int(rc[lane]) * int(rc[gid]) \
                    * total_c
        credit = group_flops[0] / peak_flops
        exposed = 0.0
        for ri, tw in enumerate(t_wire):
            hidden = min(tw, credit)
            exposed += tw - hidden
            credit += group_flops[ri + 1] / peak_flops - hidden
        worst_exposed = max(worst_exposed, exposed)

    eff = 1.0 - worst_exposed / total if total > 0 else 0.0
    # scheduled bytes of the priced plan — every pair of every round moves
    # its rows_pad rows; this is exactly exchange_bytes(plan)["wire_bytes"]
    # (the per-second totals above price per *round* over one link, so
    # they are not byte-convertible when a round carries several pairs)
    wire_rows = sum(len(r.pairs) * r.rows_pad for r in plan.rounds)
    return {
        "enabled": bool(enabled),
        "num_rounds": plan.num_rounds,
        "num_groups": plan.num_rounds + 1,
        "total_wire_s": total,
        "exposed_wire_s": worst_exposed,
        "hidden_wire_s": total - worst_exposed,
        "overlap_efficiency": eff,
        "total_wire_bytes": int(wire_rows * total_c * itemsize),
        "exposed_wire_bytes": int(worst_exposed * ici_bw),
        "num_gathers": n_gathers,
        "model": {"peak_flops": peak_flops, "ici_bw": ici_bw,
                  "itemsize": itemsize},
    }


def exchange_bytes(plan: NeighborExchange, feature_dims: Sequence[int],
                   itemsize: int = 4) -> dict:
    """Scheduled wire volume of the p2p transport per ADMM iteration.

    ``wire_bytes`` is what the ``ppermute`` rounds actually move: per round,
    every participating pair transmits the round's padded ``rows_pad``
    *node* rows (shards outside the round's partial permutation move
    nothing).  A whole-block plan wires ``n_pad`` rows per community; a
    row-exact plan only the true sizes.  ``p2p_needed_bytes`` counts only
    the true (round-padding-free) rows, so ``wire_bytes ==
    p2p_needed_bytes + padding_bytes`` exactly — the invariant
    ``verify_transport_bytes`` enforces against the mask-derived
    ``gather_bytes`` accounting.
    """
    wire_rows = sum(len(r.pairs) * r.rows_pad for r in plan.rounds)
    true_rows = sum(r.true_rows for r in plan.rounds)
    wire = sum(wire_rows * c * itemsize for c in feature_dims)
    needed = sum(true_rows * c * itemsize for c in feature_dims)
    return {"wire_bytes": wire, "p2p_needed_bytes": needed,
            "padding_bytes": wire - needed, "wire_rows": wire_rows,
            "true_rows": true_rows, "num_rounds": plan.num_rounds,
            "r_pad": plan.r_pad, "row_exact": plan.row_exact,
            "lanes_per_shard": plan.lanes_per_shard}


def verify_transport_bytes(stats: dict) -> dict:
    """Invariant check tying the p2p schedule to the mask-derived stats.

    Hard invariants (raise — true by construction, a violation means the
    schedule or accounting is broken): (a) the transport never moves more
    than the all-gather it replaces, (b) wire == true scheduled rows +
    round padding, (c) the true rows stay within the block-level
    ``needed_bytes`` the masks record (per-shard deduplication only
    shrinks them).

    ``wire_bytes <= needed_bytes`` *including* padding additionally holds
    whenever each shard hosts one community (k=1: every round row is a
    real row, zero padding) *and* the plan is whole-block — the benchmark
    sweeps and CI guards (benchmarks/check_bench.py) run in that regime
    and assert it strictly.  Row-exact plans can carry round padding even
    at k=1 (messages of different true sizes share a round), so there —
    as on multi-lane shards — padding overshoot is recorded as
    ``wire_within_needed`` rather than raised; the schedule is still
    correct, still bounded by the all-gather volume, and its *true* rows
    are strictly fewer than the whole-block plan's.
    """
    wire = stats["wire_bytes"]
    if wire > stats["full_bytes"]:
        raise ValueError(
            f"p2p transport moves more than all-gather: wire={wire} > "
            f"full={stats['full_bytes']}")
    if wire != stats["p2p_needed_bytes"] + stats["padding_bytes"]:
        raise ValueError(
            f"wire accounting inconsistent: {wire} != "
            f"{stats['p2p_needed_bytes']} + {stats['padding_bytes']}")
    if stats["p2p_needed_bytes"] > stats["needed_bytes"]:
        raise ValueError(
            f"scheduled rows exceed the mask-derived needed volume: "
            f"{stats['p2p_needed_bytes']} > {stats['needed_bytes']}")
    stats["wire_within_needed"] = wire <= stats["needed_bytes"]
    if stats.get("lanes_per_shard") == 1 and not stats.get("row_exact") \
            and not stats["wire_within_needed"]:
        raise ValueError(
            f"k=1 whole-block schedule has padding ({wire} > "
            f"{stats['needed_bytes']}) — impossible by construction, "
            f"accounting is broken")
    return stats


def second_order_from_relay(q_all: Array, a_row: Array, z_local: Array,
                            w_next: Array) -> Array:
    """s²_{l,r→m} for all r, reconstructed receiver-side (eq. 4).

    q_all:   (M, n_pad, C_next) — gathered relay aggregates q_{l,r}
    a_row:   (M, n_pad, n_pad)  — Ã_{m,r}; Ã_{r,m} = Ã_{m,r}ᵀ
    z_local: (n_pad, C_l)       — Z_{l,m}
    returns  (M, n_pad, C_next)
    """
    own_contrib = jnp.einsum("rnp,nc->rpc", a_row, z_local @ w_next)
    return q_all - own_contrib


def neighbor_preactivations(q_all: Array, a_row: Array, z_var: Array,
                            z_ref: Array, w_next: Array) -> Array:
    """Pre-activations of *every* community's next layer as a function of
    this community's variable ``z_var`` (with all other communities frozen
    at their k-th iterates, already baked into ``q_all`` via ``z_ref``):

        pre[r] = q_{l,r} + Ã_{r,m} (z_var − z_ref) W_{l+1}
               = s²_{l,r→m} + Ã_{r,m} z_var W_{l+1}

    For r ∉ N_m the Ã block is zero, so pre[r] is constant in z_var (those
    terms drop out of the gradient — the paper's neighbour-only coupling).
    """
    delta = (z_var - z_ref) @ w_next
    return q_all + jnp.einsum("rnp,nc->rpc", a_row, delta)
