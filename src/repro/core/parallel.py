"""Parallel (community-distributed) ADMM trainer — Algorithm 1 on a mesh.

Each shard on the ``comm`` mesh axis hosts ``k = M / n_shards`` community
agents (the paper's agents; k=1 when every community gets its own device).
One ADMM iteration is a single ``shard_map``-ed program:

  * W update — layer-parallel (Jacobi): per-shard φ contributions and grads
    are ``psum``-ed; the backtracking condition is evaluated on the global
    objective, so every shard takes the identical accepted τ step (this
    replaces the paper's dedicated agent M+1 with a replicated computation —
    TPU-native, no parameter server).
  * Z update — community-parallel: each community solves its ψ_{l,m}
    (eq. 5/6) locally from gathered relay aggregates (messages.py) with its
    own backtracking θ_{l,m} (lane-masked, so communities sharing a device
    still line-search independently); Z_L via per-community FISTA (eq. 7).
  * U update — local dual ascent (eq. 3).

Communication per iteration (the roofline 'collective' term) is one
exchange of Z/U/q per consumer round; the paper's p/s messages are exactly
the relayed aggregates, see messages.py.  Z_0 is static input — it is
exchanged exactly once per iteration and reused by every consumer (layer-1
input and the 1-layer dual refresh).  Two transports (``transport`` flag):

  * allgather — ``lax.all_gather`` moves every shard's payload to every
    shard, then masks to the neighbour rows.  The only transport the dense
    adjacency supports (its Z-coupling reads all M rows), and the parity
    oracle for p2p.
  * p2p (default for ``compressed=True``) — neighbour-only exchange over a
    static round schedule (messages.NeighborExchange): the community
    topology is lifted to shard-to-shard edges (per-shard union of the ELL
    neighbour indices, graph.shard_neighbor_graph), messages are coloured
    into ``lax.ppermute`` rounds by ring offset (sharding.partition.
    ring_round_coloring — each round is a partial permutation, inactive
    offsets are skipped), and each round moves a padded
    ``(rows_pad, n_pad, C)`` send buffer.  Every shard receives only the
    lane-major ``(r_pad, n_pad, C)`` buffer of rows its subproblems
    actually read — no ``(M, n_pad, C)`` gathered tensor is materialised —
    and the ELL indices are remapped host-side to receive-buffer slots.
    ``comm_stats`` records the scheduled ``wire_bytes`` ==
    true rows + round padding ≤ the all-gather ``full_bytes``, with the
    true rows bounded by the mask-derived ``needed_bytes`` (verified at
    construction by messages.verify_transport_bytes; with one community
    per shard the bound holds padding-included and the CI benchmark
    guards assert it strictly).

Adjacency representations (``compressed`` flag):

  * dense — every shard holds its k rows of the (M, M, n_pad, n_pad) block
    tensor: O(k·M·n_pad²) bytes per shard, and the Z-update coupling term
    sums over all M communities (masked): O(M·n_pad²·C) FLOPs per lane.
  * compressed — each shard holds only its lanes' ELL rows,
    (k, max_deg, n_pad, n_pad) blocks + (k, max_deg) indices/mask
    (graph.BlockCSR): O(k·max_deg·n_pad²) bytes per shard, no dense block
    tensor anywhere on device.  Aggregations run through the lane-aware ELL
    kernel (kernels.community_spmm_ell) and the coupling term is its
    transposed-block form over the max_deg neighbours only:
    O(max_deg·n_pad²·C) FLOPs per lane.  On power-law community graphs
    max_deg is ~constant in M, so per-shard memory and Z-coupling FLOPs
    stop scaling with the community count — the regime where M can grow
    past what a dense replicated layout fits on device.

Padding (``pad_mode`` flag, default "bucketed"): packed tensors keep the
fixed (M, n_pad, ...) stride, but under the bucketed scheme every
community is *logically* padded only to its power-of-two-ish size bucket
(graph.bucket_pad_sizes) — the ELL kernel's scalar-prefetched row counts
guard the pad rows out of the DMA+accumulate, the p2p transport wires
row-exact payloads (a wired community contributes its true rows, not an
n_pad block), and ``comm_stats`` reports the residual padding as
``pad_rows``/``pad_bytes``/``pad_flops``/``pad_flop_frac``
(messages.pad_stats).  "global" restores the historic
everything-pads-to-the-max behaviour; the iterates are identical either
way (pad rows are zero throughout), only processed/wired volume changes.
``adjacency_bf16=True`` (compressed only) additionally stores the ELL
block plane bf16 — half the resident adjacency bytes, f32 accumulation.

Packed device state (``packed`` flag, requires compressed + p2p): the
resident trainer state drops the (M, n_pad, …) stride entirely.  Z/U and
the static z0/labels/masks live as Σ-bucket-rows planes — each shard
holds a ``(plane_rows, C)`` plane of its lanes' bucket rows back to back
(graph.PackedDeviceLayout), so resident state bytes track the bucketed
community sizes, not M × the largest community.  The exchange runs on
the packed plane (messages.exchange_neighbors_packed — same ppermute
rounds, byte-identical wire) into a packed receive plane, and the ELL
aggregation reads it through scalar-prefetched row offsets
(kernels community_spmm_ell_packed / NeighborExchange.localized_offsets)
instead of an n_pad stride.  Subproblem math runs on blocked per-lane
views rebuilt with static take-with-fill tables — pad rows are zero
throughout (the zero-outside-counts contract), so packed iterates are
*bitwise* equal to the strided path's.  ``overlap=True`` (packed only)
additionally splits each exchange into its round-indexed buffer stages
and aggregates each arrival group as soon as its rounds are in
(double-buffering wire behind compute; the sum association changes, so
overlap parity is tolerance- rather than bit-level), and ``comm_stats``
gains an analytic overlap-efficiency metric (messages.overlap_stats)
the roofline prices exposed wire with.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import TYPE_CHECKING, NamedTuple

if TYPE_CHECKING:
    from repro.core.serial import TrainLog

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import gcn, graph, messages
from repro.core.subproblems import ADMMConfig, stale_weights
from repro.sharding.partition import CommunityBatchSampler
from repro.util import shard_map
from repro.util.compat import make_mesh

Array = jax.Array
AXIS = "comm"


class ParallelState(NamedTuple):
    """Trainer iterates.  Strided layout: zs[l] is (M, n_pad, C_l) and u
    (M, n_pad, C_L), sharded over comm.  Packed layout: zs[l] is the
    (n_shards · plane_rows, C_l) Σ-bucket-rows plane (u likewise) —
    shard s's slice holds its lanes' bucket rows back to back."""
    weights: tuple[Array, ...]   # replicated
    zs: tuple[Array, ...]        # sharded over comm
    u: Array                     # sharded
    taus: tuple[Array, ...]      # scalars, replicated
    thetas: tuple[Array, ...]    # (M,), sharded


@dataclasses.dataclass(frozen=True)
class CommunityData:
    """Device-ready community-blocked graph tensors.

    Exactly one adjacency representation is resident: dense mode holds
    ``a_blocks`` (M, M, n_pad, n_pad); compressed mode holds only the ELL
    view ``ell_blocks``/``ell_indices``/``ell_mask`` (graph.BlockCSR,
    O(nnz·n_pad²) bytes) and ``a_blocks`` is None — the shard_map trainer
    aggregates straight from the sharded ELL rows.  With
    ``adjacency_bf16=True`` (compressed only) the ELL block store is kept
    bf16 on device — half the resident adjacency bytes — and every
    aggregation accumulates in f32 (the kernel's scratch / the oracle's
    explicit upcast).

    ``row_counts``/``nbr_counts`` carry the ragged (bucketed) per-lane and
    per-neighbour padded row counts the ELL kernel's pad-row guards key
    off; ``row_mask`` masks packed (M, n_pad) tensors down to true rows
    (metrics / Lagrangian).  Under the global pad scheme the counts are
    simply n_pad everywhere.

    With ``packed_layout`` set (graph.PackedDeviceLayout), z0 / labels /
    train_mask / test_mask are stored as Σ-bucket-rows planes —
    (n_shards · plane_rows, …) instead of (M, n_pad, …) — matching the
    packed trainer state; ``row_mask`` stays blocked (it only feeds the
    host-jit metrics, which unpack the planes anyway).
    """
    a_blocks: "Array | None"   # (M, M, n_pad, n_pad) — dense mode only
    z0: Array            # (M, n_pad, C0) | packed (total_rows, C0)
    labels: Array        # (M, n_pad) int32 | packed (total_rows,)
    train_mask: Array    # (M, n_pad) f32 | packed (total_rows,)
    test_mask: Array     # (M, n_pad) f32 | packed (total_rows,)
    neighbor_mask: Array  # (M, M) bool
    denom: Array         # scalar — global labeled-node count
    row_mask: Array       # (M, n_pad) float32 — 1 = true node row
    # block-compressed Ã (ELL view) — compressed mode only
    ell_blocks: "Array | None" = None    # (M, max_deg, n_pad, n_pad)
    ell_indices: "Array | None" = None   # (M, max_deg) int32
    ell_mask: "Array | None" = None      # (M, max_deg) float32
    row_counts: "Array | None" = None    # (M,) int32
    nbr_counts: "Array | None" = None    # (M, max_deg) int32
    packed_layout: "graph.PackedDeviceLayout | None" = None

    @property
    def compressed(self) -> bool:
        return self.a_blocks is None

    @property
    def packed(self) -> bool:
        return self.packed_layout is not None

    @property
    def adjacency_bf16(self) -> bool:
        return (self.ell_blocks is not None
                and self.ell_blocks.dtype == jnp.bfloat16)

    @property
    def num_parts(self) -> int:
        if self.packed_layout is not None:
            return self.packed_layout.num_parts
        return int(self.z0.shape[0])

    @property
    def adjacency_nbytes(self) -> int:
        """Device-resident adjacency bytes of this representation."""
        if self.compressed:
            return (self.ell_blocks.nbytes + self.ell_indices.nbytes
                    + self.ell_mask.nbytes)
        return self.a_blocks.nbytes


def community_data(g: graph.Graph, layout: graph.CommunityLayout,
                   compressed: bool = False,
                   adjacency_bf16: bool = False,
                   device_layout: "graph.PackedDeviceLayout | None" = None
                   ) -> CommunityData:
    if adjacency_bf16 and not compressed:
        raise ValueError("adjacency_bf16=True requires compressed=True — "
                         "only the ELL block store has a bf16 path")
    if device_layout is not None and not compressed:
        raise ValueError("packed device state requires compressed=True — "
                         "the dense block tensor keeps the n_pad stride")
    if compressed:
        csr = layout.compress()
        rows, nbrs = csr.ell_row_counts()
        block_dt = jnp.bfloat16 if adjacency_bf16 else jnp.float32
        adj = {"a_blocks": None,
               "ell_blocks": jnp.asarray(csr.ell_blocks, dtype=block_dt),
               "ell_indices": jnp.asarray(csr.ell_indices),
               "ell_mask": jnp.asarray(csr.ell_mask),
               "row_counts": jnp.asarray(rows),
               "nbr_counts": jnp.asarray(nbrs)}
    else:
        adj = {"a_blocks": jnp.asarray(layout.a_blocks)}
    if device_layout is not None:
        # Σ-bucket-rows planes: pad rows outside the bucket counts are
        # zero by the layout contract, so pack is lossless
        def dev(x):
            return np.asarray(device_layout.pack_state(layout.pack(x)))
    else:
        def dev(x):
            return layout.pack(x)
    return CommunityData(
        z0=jnp.asarray(dev(g.features)),
        labels=jnp.asarray(dev(g.labels.astype(np.int32))),
        train_mask=jnp.asarray(dev(g.train_mask.astype(np.float32))),
        test_mask=jnp.asarray(dev(g.test_mask.astype(np.float32))),
        neighbor_mask=jnp.asarray(layout.neighbor_mask),
        denom=jnp.asarray(float(g.train_mask.sum())),
        row_mask=jnp.asarray(layout.node_mask.astype(np.float32)),
        packed_layout=device_layout,
        **adj,
    )


# ---------------------------------------------------------------------------
# trainer configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    """Every mode flag of ``ParallelADMMTrainer``, validated in one place.

    The flags form a dependency ladder the trainer's subsystems rely on —
    packed planes only route through ELL offsets, the row-exact exchange
    only feeds packed planes, sampling only restricts a p2p round
    schedule — and ``__post_init__`` enforces the whole ladder with the
    same messages the trainer's historic inline checks raised, so every
    construction path (presets, CLI, benchmarks, the deprecation shim)
    fails identically.  ``transport=None`` resolves here exactly as the
    trainer historically did: p2p when compressed, the all-gather oracle
    otherwise.  ``partitioner=None`` stays None — its resolution depends
    on whether a precomputed partition is supplied, which only the
    trainer knows.

    Minibatching (``batch_fraction`` not None) engages stochastic
    community sampling: each ADMM round runs the W/Z/U sweep on a seeded
    shard batch only (sharding.partition.CommunityBatchSampler), with
    unsampled communities' consensus terms carried at their stale
    iterates under a ``stale_decay``-damped penalty
    (subproblems.stale_weights).  ``batch_fraction=1.0`` samples every
    shard every round and is bitwise-identical to the full-batch packed
    trainer; ``None`` (the default) builds no sampling machinery at all.
    Sampling composes with ``overlap=True``: each compiled batch derives
    its arrival-group schedule from its own restricted sub-plan.

    ``fused=True`` (requires ``packed``) routes the Z-update sites —
    target/relay/dual aggregation followed by a GEMM — through the fused
    aggregation→GEMM path (kernels.ops.community_spmm_ell_fused): the
    aggregated (k, n_pad, C) stack stays in VMEM scratch (TPU) or is
    reassociated away (oracle), never materialised in HBM.  The W-update
    keeps the raw aggregate (its line search re-evaluates the GEMM under
    a varying W — fusing there would repeat the whole aggregation per
    backtracking probe).  Inert on 1-shard meshes (no packed wire), where
    the program is bitwise the unfused one; multi-shard fused-vs-unfused
    parity is dot-reassociation tolerance.
    """
    compressed: bool = False
    transport: "str | None" = None
    partitioner: "str | None" = None
    pad_mode: str = "bucketed"
    packed: bool = False
    overlap: bool = False
    fused: bool = False
    comm_bf16: bool = False
    adjacency_bf16: bool = False
    use_kernel: bool = False
    batch_fraction: "float | None" = None
    stale_decay: float = 0.5
    sample_seed: int = 0

    def __post_init__(self):
        transport = self.transport
        if transport is None:
            transport = "p2p" if self.compressed else "allgather"
            object.__setattr__(self, "transport", transport)
        if transport not in ("p2p", "allgather"):
            raise ValueError(f"unknown transport {transport!r}; "
                             f"expected 'p2p' or 'allgather'")
        if transport == "p2p" and not self.compressed:
            raise ValueError("transport='p2p' requires compressed=True — "
                             "the dense Z-coupling reads all M payload rows")
        if self.packed and not self.compressed:
            raise ValueError("packed=True requires compressed=True — the "
                             "packed plane is only routed through ELL "
                             "offsets, never a dense Z-coupling")
        if self.packed and transport != "p2p":
            raise ValueError("packed=True requires transport='p2p' — the "
                             "plane layout exists to feed the row-exact "
                             "exchange; an all-gather would re-materialise "
                             "the strided (M, n_pad, C) payload")
        if self.overlap and not self.packed:
            raise ValueError("overlap=True requires packed=True — the "
                             "staged exchange snapshots are packed planes")
        if self.fused and not self.packed:
            raise ValueError("fused=True requires packed=True — the fused "
                             "aggregation→GEMM kernel reads the packed "
                             "receive plane through ELL offsets")
        if self.pad_mode not in ("global", "bucketed"):
            raise ValueError(f"unknown pad_mode {self.pad_mode!r}; "
                             f"expected 'global' or 'bucketed'")
        if self.adjacency_bf16 and not self.compressed:
            raise ValueError("adjacency_bf16=True requires compressed=True")
        if self.batch_fraction is not None:
            if not 0.0 < self.batch_fraction <= 1.0:
                raise ValueError(f"batch_fraction must be in (0, 1], got "
                                 f"{self.batch_fraction!r}")
            if not self.packed:
                raise ValueError("batch_fraction requires packed=True — "
                                 "the sampled sweep runs on the sampled "
                                 "shards' packed planes")
        if not 0.0 < self.stale_decay <= 1.0:
            raise ValueError(f"stale_decay must be in (0, 1], got "
                             f"{self.stale_decay!r}")

    @classmethod
    def from_cli_args(cls, args) -> "TrainerConfig":
        """Build from an argparse namespace (examples' CLI): every flag
        is read by its ``dest`` name, missing attributes keep the field
        default — one mapping instead of a kwarg list per driver."""
        kw = {}
        for f in dataclasses.fields(cls):
            if hasattr(args, f.name):
                kw[f.name] = getattr(args, f.name)
        return cls(**kw)


# named presets — attached after the class body because ``packed`` is
# both a field and a constructor name (a def inside the class body would
# shadow the dataclass field's default)
def _preset_dense(cls, **kw) -> TrainerConfig:
    """The dense-adjacency all-gather baseline."""
    kw.setdefault("compressed", False)
    return cls(**kw)


def _preset_p2p(cls, **kw) -> TrainerConfig:
    """Block-compressed adjacency over the neighbour-only p2p transport."""
    kw.setdefault("compressed", True)
    kw.setdefault("transport", "p2p")
    return cls(**kw)


def _preset_packed(cls, **kw) -> TrainerConfig:
    """Packed Σ-bucket-rows resident state over row-exact p2p."""
    kw.setdefault("compressed", True)
    kw.setdefault("transport", "p2p")
    kw.setdefault("packed", True)
    return cls(**kw)


def _preset_minibatch(cls, batch_fraction: float = 0.25,
                      **kw) -> TrainerConfig:
    """Stochastic community minibatching on the packed trainer."""
    kw.setdefault("compressed", True)
    kw.setdefault("transport", "p2p")
    kw.setdefault("packed", True)
    kw.setdefault("batch_fraction", batch_fraction)
    return cls(**kw)


TrainerConfig.dense = classmethod(_preset_dense)
TrainerConfig.p2p = classmethod(_preset_p2p)
TrainerConfig.packed = classmethod(_preset_packed)
TrainerConfig.minibatch = classmethod(_preset_minibatch)

# the historic flag kwargs the deprecation shim still accepts
_LEGACY_FLAGS = ("use_kernel", "comm_bf16", "compressed", "transport",
                 "partitioner", "pad_mode", "adjacency_bf16", "packed",
                 "overlap")


# ---------------------------------------------------------------------------
# backtracking primitives
# ---------------------------------------------------------------------------

def backtracking_step_psum(local_obj, x, tau0, admm: ADMMConfig):
    """Majorize-minimize step on the *global* objective psum(local_obj):
    every shard evaluates the same condition and accepts the same τ."""
    val_loc, grad_loc = jax.value_and_grad(local_obj)(x)
    val = jax.lax.psum(val_loc, AXIS)
    grad = jax.lax.psum(grad_loc, AXIS)
    g_sq = jnp.vdot(grad, grad).real

    def global_obj(w):
        return jax.lax.psum(local_obj(w), AXIS)

    def cond(carry):
        tau, it = carry
        x_new = x - grad / tau
        bound = val - 0.5 * g_sq / tau
        tol = admm.backtrack_rtol * (jnp.abs(bound) + 1e-12)
        return (bound + tol < global_obj(x_new)) & \
            (it < admm.max_backtracks)

    def body(carry):
        tau, it = carry
        return tau * admm.backtrack_growth, it + 1

    tau0 = jnp.maximum(tau0 / admm.backtrack_growth, 1e-8)
    tau, _ = jax.lax.while_loop(cond, body, (tau0, jnp.asarray(0)))
    return x - grad / tau, tau


def backtracking_step_lanes(obj_lanes, x, theta0, admm: ADMMConfig):
    """Per-lane majorize-minimize step (paper's per-(l,m) θ backtracking).

    obj_lanes: (k, n, C) -> (k,) per-community objective values.
    x: (k, n, C); theta0: (k,).  Lanes line-search independently: the loop
    runs until every lane accepts, frozen lanes stop doubling.
    """
    vals = obj_lanes(x)                                  # (k,)
    grads = jax.grad(lambda z: obj_lanes(z).sum())(x)    # (k, n, C) separable
    g_sq = jnp.sum(grads * grads, axis=(1, 2))           # (k,)

    def accepted(theta):
        x_new = x - grads / theta[:, None, None]
        bound = vals - 0.5 * g_sq / theta
        tol = admm.backtrack_rtol * (jnp.abs(bound) + 1e-12)
        return bound + tol >= obj_lanes(x_new)

    def cond(carry):
        theta, done, it = carry
        return (~jnp.all(done)) & (it < admm.max_backtracks)

    def body(carry):
        theta, done, it = carry
        theta = jnp.where(done, theta, theta * admm.backtrack_growth)
        done = done | accepted(theta)
        return theta, done, it + 1

    theta0 = jnp.maximum(theta0 / admm.backtrack_growth, 1e-8)
    done0 = accepted(theta0)
    theta, _, _ = jax.lax.while_loop(cond, body,
                                     (theta0, done0, jnp.asarray(0)))
    return x - grads / theta[:, None, None], theta


def fista_lanes(admm: ADMMConfig, b, u, labels, mask, z_init, denom):
    """Eq. (7) per community lane: R(Z,Y_m) + ⟨U_m, Z−B_m⟩ + ρ/2‖Z−B_m‖².

    All arrays carry a leading lane dim k; each lane runs its own Lipschitz
    backtracking (lane-masked), so communities on the same device still
    solve their subproblems exactly as independent agents would.
    """

    def obj_lanes(z):                                    # (k,) values
        logp = jax.nn.log_softmax(z, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        ce = jnp.sum(nll * mask, axis=1) / denom
        r = z - b
        lin = jnp.sum(u * r, axis=(1, 2))
        quad = 0.5 * admm.rho * jnp.sum(r * r, axis=(1, 2))
        return ce + lin + quad

    grad_fn = jax.grad(lambda z: obj_lanes(z).sum())

    def step(carry, _):
        z, y, t, lip = carry
        vals_y = obj_lanes(y)
        g = grad_fn(y)
        g_sq = jnp.sum(g * g, axis=(1, 2))

        def accepted(lip):
            z_new = y - g / lip[:, None, None]
            bound = vals_y - 0.5 * g_sq / lip
            tol = admm.backtrack_rtol * (jnp.abs(bound) + 1e-12)
            return obj_lanes(z_new) <= bound + tol

        def cond(carry):
            lip, done, it = carry
            return (~jnp.all(done)) & (it < admm.max_backtracks)

        def body(carry):
            lip, done, it = carry
            lip = jnp.where(done, lip, lip * admm.backtrack_growth)
            done = done | accepted(lip)
            return lip, done, it + 1

        lip, _, _ = jax.lax.while_loop(
            cond, body, (lip, accepted(lip), jnp.asarray(0)))
        z_new = y - g / lip[:, None, None]
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = z_new + ((t - 1.0) / t_new) * (z_new - z)
        return (z_new, y_new, t_new, lip * 0.9), None

    k = z_init.shape[0]
    init = (z_init, z_init, jnp.asarray(1.0),
            jnp.full((k,), admm.rho + 1.0))
    (z, _, _, _), _ = jax.lax.scan(step, init, None, length=admm.fista_iters)
    return z


# ---------------------------------------------------------------------------
# one ADMM iteration, per-shard body (k communities per shard)
# ---------------------------------------------------------------------------

def _iteration_body(cfg: gcn.GCNConfig, admm: ADMMConfig, use_kernel: bool,
                    comm_bf16: bool, compressed: bool,
                    plan: "messages.NeighborExchange | None",
                    overlap: bool, fused: bool,
                    packed_aux: "dict | None",
                    mb_aux: "dict | None",
                    adj, nbr_row, z0_loc, labels_loc, mask_loc, denom,
                    ws, zs_loc, u_loc, taus, thetas, nbr_decay=None):
    """Shapes per shard: nbr_row (k,M); z*_loc (k,n,C); thetas[l] (k,).

    ``adj`` is the shard's adjacency rows — dense mode: a_row (k,M,n,n);
    compressed mode: (ell_rows (k,max_deg,n,n), ell_idx (k,max_deg),
    ell_msk (k,max_deg), ell_rcnt (k,), ell_ncnt (k,max_deg)) with the
    ragged row counts feeding the ELL kernel's pad-row guards.  ``plan``
    selects the transport: None means
    all-gather (ell_idx holds *global* community ids into the gathered
    (M,n,C) payload); a NeighborExchange means neighbour-only ppermute
    rounds (ell_idx is pre-remapped to slots of the (r_pad,n,C) receive
    buffer, and no (M,n,C) tensor exists in this body).

    ``packed_aux`` (packed state mode) is a dict of *static* host tables:
    z*_loc/u_loc arrive as this shard's Σ-bucket-rows planes, are
    rebuilt into the blocked views above via take-with-fill (bitwise
    lossless under the zero-outside-counts contract), and the updated
    Z/U are re-packed on exit.  With a plan, the exchange itself runs on
    the packed plane and the ELL aggregation reads the packed receive
    plane through per-slot row offsets; ``overlap`` further splits the
    aggregation by arrival round so each group's compute can overlap the
    later ppermute rounds.

    Every ``gather`` returns an ``(agg, blk)`` pair: ``agg`` feeds
    ``rowagg`` (the packed plane / its staged snapshots in packed mode)
    and ``blk`` is the blocked row view every other consumer indexes.
    Outside packed mode both elements are the same buffer.

    ``mb_aux`` (stochastic minibatching — requires packed + compressed)
    carries the *static* per-shard sample mask table of this compiled
    batch: ``smask[s, j]`` is 1.0 iff shard s's lane j is sampled this
    round (shard-granular, so a shard's lanes agree).  ``nbr_decay`` is
    the traced (k, max_deg) staleness weight d_r = stale_decay**age_r of
    each lane's stored neighbours (subproblems.stale_weights).  The body
    then (a) masks unsampled lanes' residuals out of the W-update psums,
    (b) scales every Z-coupling penalty to neighbour r by d_r — √d_r is
    folded into ``wt`` so the squared residuals carry the full weight,
    and the last layer's dual term gets the second √d_r explicitly —
    and (c) applies the Z/θ/U updates through a lane ``where`` so
    unsampled lanes keep their iterates bit-for-bit.  Every knob is
    exact-at-identity (mask 1.0, d 1.0 → multiplies by 1.0, selects of
    the new value), so a full batch reproduces the unsampled program
    bitwise.
    """
    f = gcn.activation_fn(cfg.activation)
    num_layers = cfg.num_layers
    m_total = nbr_row.shape[1]
    nbrf = nbr_row.astype(jnp.float32)           # (k, M) 1/0 neighbour rows
    # union of this shard's lanes' neighbourhoods: the only communities
    # whose payload rows any local subproblem reads
    shard_nbr = jnp.max(nbrf, axis=0)            # (M,)

    if mb_aux is not None:
        smask = jnp.asarray(mb_aux["smask"])[jax.lax.axis_index(AXIS)]
        smask_b = smask > 0                      # (k,) sampled lanes
        sm = smask[:, None, None]                # residual mask, (k,1,1)
        sdr = jnp.sqrt(nbr_decay)                # √d_r, (k, max_deg)
    else:
        smask_b = sm = sdr = None

    packed_wire = packed_aux is not None and plan is not None
    if packed_aux is not None:
        sid0 = jax.lax.axis_index(AXIS)
        kk, npd = packed_aux["k"], packed_aux["n"]
        unp_tbl = jnp.asarray(packed_aux["unpack"])[sid0]    # (k·n,)
        pk_tbl = jnp.asarray(packed_aux["pack"])[sid0]       # (plane_rows,)

        def from_plane(p):
            flat = jnp.take(p, unp_tbl, axis=0, mode="fill", fill_value=0)
            return flat.reshape((kk, npd) + p.shape[1:])

        def to_plane(blk):
            flat = blk.reshape((kk * npd,) + blk.shape[2:])
            return jnp.take(flat, pk_tbl, axis=0, mode="fill", fill_value=0)

        z0_loc = from_plane(z0_loc)
        labels_loc = from_plane(labels_loc)
        mask_loc = from_plane(mask_loc)
        zs_loc = tuple(from_plane(z) for z in zs_loc)
        u_loc = from_plane(u_loc)

    if compressed:
        ell_rows, ell_idx, ell_msk, ell_rcnt, ell_ncnt = adj
        ell_f = ell_msk.astype(jnp.float32)      # (k, max_deg)
        if use_kernel:
            from repro.kernels import ops as kops

            def agg_blocked(zh):
                # scalar-prefetched indices steer the Z-block DMA; padding
                # slots skip via @pl.when and the row-count guards drop pad
                # rows of ragged (bucketed) layouts: work ∝ true block rows
                return kops.community_spmm_ell(ell_rows, ell_idx, ell_msk,
                                               zh, ell_rcnt, ell_ncnt)
        else:
            def agg_blocked(zh):         # Σ_{d} Ã[m,d] Z[idx[m,d]] per lane
                zg = zh[ell_idx] * ell_f[..., None, None]
                return jnp.einsum("kdip,kdpc->kic",
                                  ell_rows.astype(jnp.float32),
                                  zg.astype(jnp.float32))
    elif use_kernel:
        a_row = adj
        from repro.kernels import ops as kops

        def agg_blocked(zh):
            # per-lane neighbour rows engage the kernel's @pl.when block
            # skipping: work ∝ nnz blocks, not M²
            return kops.community_spmm(a_row, zh, nbr_row)
    else:
        a_row = adj

        def agg_blocked(zh):             # Σ_{r∈N_m} Ã_{m,r} Z_r per lane
            return jnp.einsum("kmip,mpc->kic",
                              a_row * nbrf[:, :, None, None], zh)

    if packed_wire:
        off_lanes = jnp.asarray(packed_aux["offsets"])[sid0]   # (k, D)
        lane_n = jnp.arange(npd)
        if use_kernel:
            from repro.kernels import ops as kops

            def agg_plane(plane, msk):
                # offset-indexed kernel: the Z DMA reads the packed
                # receive plane at the scalar-prefetched slot offsets
                return kops.community_spmm_ell_packed(
                    ell_rows, off_lanes, msk, plane, ell_rcnt, ell_ncnt)
        else:
            def agg_plane(plane, msk):
                rows = off_lanes[..., None] + lane_n[None, None, :]
                valid = (lane_n[None, None, :] < ell_ncnt[..., None]) \
                    & (msk[..., None] != 0)
                rows = jnp.where(valid, rows, plane.shape[0])
                zg = jnp.take(plane, rows.reshape(-1), axis=0,
                              mode="fill", fill_value=0)
                zg = zg.reshape(rows.shape + plane.shape[1:])
                return jnp.einsum("kdip,kdpc->kic",
                                  ell_rows.astype(jnp.float32),
                                  zg.astype(jnp.float32))

        if overlap:
            grp_lanes = jnp.asarray(packed_aux["groups"])[sid0]  # (k, D)

            def rowagg(x):
                # double-buffered schedule: stage g of the exchange holds
                # everything rounds < g delivered, so group g's partial
                # aggregation depends on no later ppermute — XLA is free
                # to run it while those rounds are still on the wire
                stages = x[0]
                acc = agg_plane(stages[0], ell_f * (grp_lanes == 0))
                for gi in range(1, len(stages)):
                    acc = acc + agg_plane(stages[gi],
                                          ell_f * (grp_lanes == gi))
                return acc
        else:
            def rowagg(x):
                return agg_plane(x[0], ell_f)
    else:
        def rowagg(x):
            return agg_blocked(x[0])

    # ``rowagg_mm(x, w)`` is the aggregation→GEMM composite the Z-update
    # sites consume.  Unfused it is literally ``rowagg(x) @ w`` (bitwise
    # the historic program); fused on the packed wire it runs the one-pass
    # kernel / the reassociated A·(Z·W) oracle, so the aggregated
    # (k, n, C_in) stack never exists outside VMEM scratch.  Overlap
    # composes by linearity: (Σ_g agg_g) @ W == Σ_g (agg_g @ W), each
    # arrival group's fused call depending only on its own stage buffer.
    if packed_wire and fused:
        if use_kernel:
            from repro.kernels import ops as kops

            def agg_plane_mm(plane, msk, w):
                return kops.community_spmm_ell_fused(
                    ell_rows, off_lanes, msk, plane, w, ell_rcnt, ell_ncnt)
        else:
            def agg_plane_mm(plane, msk, w):
                # reassociated oracle: pre-multiplying the packed plane
                # keeps the compiled CPU program aggregate-free too
                return agg_plane(plane @ w, msk)

        if overlap:
            def rowagg_mm(x, w):
                stages = x[0]
                acc = agg_plane_mm(stages[0], ell_f * (grp_lanes == 0), w)
                for gi in range(1, len(stages)):
                    acc = acc + agg_plane_mm(stages[gi],
                                             ell_f * (grp_lanes == gi), w)
                return acc
        else:
            def rowagg_mm(x, w):
                return agg_plane_mm(x[0], ell_f, w)
    else:
        def rowagg_mm(x, w):
            return rowagg(x) @ w

    if packed_wire:
        ru_tbl = jnp.asarray(packed_aux["recv_unpack"])[sid0]  # (r_pad·n,)

        def gather(x_loc):
            """packed p2p: pack the blocked local rows onto this shard's
            plane, run the ppermute schedule on packed row payloads
            (byte-identical wire to the strided plan), and rebuild the
            (r_pad, n, C) blocked view for the row-indexed consumers."""
            plane = to_plane(x_loc)
            res = messages.exchange_neighbors_packed(
                plan, plane, AXIS, comm_bf16=comm_bf16, staged=overlap)
            buf = res[-1] if overlap else res
            flat = jnp.take(buf, ru_tbl, axis=0, mode="fill", fill_value=0)
            blk = flat.reshape((plan.r_pad, npd) + x_loc.shape[2:])
            return (res, blk)
    elif plan is not None:
        def gather(x_loc):
            """p2p transport: (k, n, C) local -> (r_pad, n, C) neighbour
            receive buffer via the static ppermute round schedule.  Only
            the rows this shard's subproblems read ever hit the wire (plus
            round padding); consumers index the buffer through the
            pre-localized ELL slots."""
            buf = messages.exchange_neighbors(plan, x_loc, AXIS,
                                              comm_bf16=comm_bf16)
            return (buf, buf)
    else:
        def gather(x_loc):
            """allgather transport: (k, n, C) local -> (M, n, C) global
            (community-major order), masked down to the rows
            r ∈ ∪_lanes N_m that this shard's subproblems actually read —
            the mask documents/verifies the needed volume the p2p transport
            realizes (``ParallelADMMTrainer.comm_stats``).

            With ``comm_bf16`` the paper's p/s message payloads travel in
            bf16 (half the collective bytes; §Perf; messages.bf16_wire) and
            are restored to f32 for the local subproblem math."""
            dt = x_loc.dtype
            gather_all = partial(jax.lax.all_gather, axis_name=AXIS)
            g = messages.bf16_wire(gather_all, x_loc) if comm_bf16 \
                else gather_all(x_loc)               # (n_shards, k, n, C)
            g = g.reshape((m_total,) + x_loc.shape[1:])
            g = g * shard_nbr[:, None, None].astype(dt)
            return (g, g)

    # gathered k-th iterates — one communication round per ADMM iteration.
    # Z_0 is static input: gather it exactly once per step and reuse it for
    # the layer-1 input and (1-layer nets) the dual refresh.
    zh0 = gather(z0_loc)                        # Z_0, gathered once
    zh = [gather(z) for z in zs_loc]            # Z_1..Z_L
    zh_in = [zh0] + zh[:-1]                     # layer inputs

    # ---- Line 3: W update (layer-parallel, Jacobi over Z^k) ----
    new_ws, new_taus = [], []
    for l in range(num_layers):
        agg = rowagg(zh_in[l])                  # (k, n, C_{l-1})

        # minibatch: unsampled lanes' constraints leave the (psum-ed)
        # W objective entirely — their residuals mask to exact zeros
        if l < num_layers - 1:
            def local_obj(w, agg=agg, z=zs_loc[l]):
                r = z - f(agg @ w)
                if sm is not None:
                    r = r * sm
                return 0.5 * admm.nu * jnp.vdot(r, r).real
        else:
            def local_obj(w, agg=agg, z=zs_loc[l]):
                r = z - agg @ w
                if sm is not None:
                    r = r * sm
                return jnp.vdot(u_loc, r).real + \
                    0.5 * admm.rho * jnp.vdot(r, r).real
        w_new, tau = backtracking_step_psum(local_obj, ws[l], taus[l], admm)
        new_ws.append(w_new)
        new_taus.append(tau)

    # ---- Line 4: Z update (community-parallel, reads W^{k+1}, Z^k) ----
    new_zs, new_thetas = [], []
    for l in range(1, num_layers):              # hidden layers (eq. 5/6)
        w_l, w_next = new_ws[l - 1], new_ws[l]
        target1 = f(rowagg_mm(zh_in[l - 1], w_l))            # (k, n, C_l)
        # relay aggregates q_{l,r} (eq. 4 second-order payload), all r
        q_loc = rowagg_mm(zh[l - 1], w_next)                 # (k, n, C_next)
        q_all = gather(q_loc)[1]                             # blocked rows
        z_ref = zs_loc[l - 1]

        # Coupling term of ψ (paper eq. 5/6): every neighbour community r's
        # next-layer pre-activation as a function of my lanes,
        #   pre[j, r] = q_r + Ã_{r,m_j} (z_j − z_ref_j) W.
        # Lane m's ψ only sums r ∈ N_m ∪ {m} — the r ∉ N_m residuals are
        # constants in z (zero gradient) and drop from the objective.
        if compressed:
            # neighbour-compressed form: enumerate the max_deg stored
            # neighbours only.  Ã_{r,m} = Ã_{m,r}ᵀ (Ã symmetric), so the
            # stored row blocks are consumed transposed ("kdnp,knc->kdpc")
            # — the gather-transpose trick of second_order_from_relay.
            # O(max_deg·n_pad²·C) per lane instead of the dense O(M·…).
            def pre_nbr(z, q_all=q_all, z_ref=z_ref, w_next=w_next):
                delta = (z - z_ref) @ w_next                 # (k, n, C)
                own = jnp.einsum("kdnp,knc->kdpc",
                                 ell_rows.astype(jnp.float32), delta)
                return q_all[ell_idx] + own                  # (k, D, n, C)

            # staleness damping: √d_r folded into the coupling weight, so
            # every squared residual carries the full d_r (exact identity
            # when all ages are 0: ell_f · 1.0 is bitwise ell_f)
            wt = (ell_f * sdr if sdr is not None
                  else ell_f)[..., None, None]               # (k, D, 1, 1)

            def nbr_vals(x_all):
                """(M, n, C) gathered payload -> this lane's (k, D, n, C)."""
                return x_all[ell_idx]
        else:
            def pre_nbr(z, q_all=q_all, z_ref=z_ref, w_next=w_next):
                delta = (z - z_ref) @ w_next                 # (k, n, C)
                return q_all[None] + jnp.einsum("kmnp,knc->kmpc",
                                                a_row, delta)

            wt = nbrf[:, :, None, None]                      # (k, M, 1, 1)

            def nbr_vals(x_all):
                return x_all[None]                           # (1, M, n, C)

        if l + 1 < num_layers:
            zh_next = zh[l][1]

            def obj_lanes(z, target1=target1, pre_nbr=pre_nbr,
                          zh_next=zh_next):
                r1 = z - target1
                v1 = 0.5 * admm.nu * jnp.sum(r1 * r1, axis=(1, 2))
                r2 = (nbr_vals(zh_next) - f(pre_nbr(z))) * wt
                v2 = 0.5 * admm.nu * jnp.sum(r2 * r2, axis=(1, 2, 3))
                return v1 + v2
        else:
            zh_last, uh = zh[l][1], gather(u_loc)[1]

            def obj_lanes(z, target1=target1, pre_nbr=pre_nbr,
                          zh_last=zh_last, uh=uh):
                r1 = z - target1
                v1 = 0.5 * admm.nu * jnp.sum(r1 * r1, axis=(1, 2))
                r2 = (nbr_vals(zh_last) - pre_nbr(z)) * wt
                uv = nbr_vals(uh)
                if sdr is not None:
                    # second √d_r: r2 carries one, so the dual term
                    # ⟨U_r, ·⟩ scales by the full staleness weight d_r
                    uv = uv * sdr[..., None, None]
                lin = jnp.sum(uv * r2, axis=(1, 2, 3))
                quad = 0.5 * admm.rho * jnp.sum(r2 * r2, axis=(1, 2, 3))
                return v1 + lin + quad

        z_new, theta = backtracking_step_lanes(
            obj_lanes, zs_loc[l - 1], thetas[l - 1], admm)
        if smask_b is not None:
            # unsampled lanes keep their iterates bit-for-bit (exact
            # block-coordinate step on the sampled blocks)
            z_new = jnp.where(smask_b[:, None, None], z_new, zs_loc[l - 1])
            theta = jnp.where(smask_b, theta, thetas[l - 1])
        new_zs.append(z_new)
        new_thetas.append(theta)

    # ---- Z_L: per-community FISTA prox (eq. 7) ----
    b = rowagg_mm(zh_in[num_layers - 1], new_ws[-1])
    z_last = fista_lanes(admm, b, u_loc, labels_loc, mask_loc,
                         zs_loc[-1], denom)
    if smask_b is not None:
        z_last = jnp.where(smask_b[:, None, None], z_last, zs_loc[-1])
    new_zs.append(z_last)
    new_thetas.append(thetas[-1])

    # ---- Line 5: dual ascent (eq. 3) with updated iterates ----
    zh_pen_new = gather(new_zs[num_layers - 2]) if num_layers >= 2 \
        else zh0
    b_new = rowagg_mm(zh_pen_new, new_ws[-1])
    new_u = u_loc + admm.rho * (new_zs[-1] - b_new)
    if smask_b is not None:
        new_u = jnp.where(smask_b[:, None, None], new_u, u_loc)

    if packed_aux is not None:
        # carry state between steps in the packed plane — the blocked
        # (k, n, C) iterates never leave this body
        new_zs = [to_plane(z) for z in new_zs]
        new_u = to_plane(new_u)

    return (tuple(new_ws), tuple(new_zs), new_u,
            tuple(new_taus), tuple(new_thetas))


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class ParallelADMMTrainer:
    """The paper's 'Parallel ADMM': M community agents on a device mesh."""

    def __init__(self, cfg: gcn.GCNConfig, admm: ADMMConfig, g: graph.Graph,
                 num_parts: int, mesh: Mesh | None = None, seed: int = 0,
                 config: TrainerConfig | None = None,
                 part: np.ndarray | None = None,
                 **legacy_flags):
        if legacy_flags:
            unknown = sorted(set(legacy_flags) - set(_LEGACY_FLAGS))
            if unknown:
                raise TypeError(
                    f"ParallelADMMTrainer got unexpected keyword arguments "
                    f"{unknown}; pass config=TrainerConfig(...)")
            if config is not None:
                raise ValueError(
                    "pass either config=TrainerConfig(...) or the legacy "
                    "flag kwargs, not both")
            warnings.warn(
                "ParallelADMMTrainer flag kwargs are deprecated; pass "
                "config=TrainerConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            config = TrainerConfig(**legacy_flags)
        elif config is None:
            config = TrainerConfig()
        # all cross-flag validation lives in TrainerConfig.__post_init__
        self.config = config
        self.cfg, self.admm, self.graph = cfg, admm, g
        self.compressed = compressed = config.compressed
        self.transport = transport = config.transport
        self.packed = packed = config.packed
        self.overlap = overlap = config.overlap
        self.fused = fused = config.fused
        self.pad_mode = pad_mode = config.pad_mode
        use_kernel = config.use_kernel
        comm_bf16 = config.comm_bf16
        adjacency_bf16 = config.adjacency_bf16
        partitioner = config.partitioner
        if part is None:
            partitioner = partitioner or "bfs_kl"
            part = graph.partition_graph(g.num_nodes, g.edges, num_parts,
                                         seed=seed, method=partitioner)
        else:
            # caller-supplied partition; a caller that computed it with
            # partition_graph may pass ``partitioner`` so the stats stay
            # honestly labelled (no re-partition just for the tag)
            partitioner = partitioner or "precomputed"
        self.partitioner = partitioner
        self.partition_stats = graph.partition_quality(
            g.num_nodes, g.edges, part, num_parts)
        self.layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                                   compressed=compressed,
                                                   pad_mode=pad_mode)
        m = int(np.asarray(self.layout.neighbor_mask).shape[0])

        if mesh is None:
            n_dev = len(jax.devices())
            n_shards = max(d for d in range(1, n_dev + 1) if m % d == 0)
            mesh = make_mesh((n_shards,), (AXIS,),
                             devices=jax.devices()[:n_shards])
        self.mesh = mesh
        n_shards = mesh.shape[AXIS]

        # packed state: each shard's Z/U/z0/label rows live back to back at
        # their bucket row counts on a flat plane — resident bytes track
        # true community size, not M·n_pad (docs/layout.md)
        self.packed_layout = self.layout.device_layout(n_shards) \
            if packed else None
        self.data = community_data(g, self.layout, compressed=compressed,
                                   adjacency_bf16=adjacency_bf16,
                                   device_layout=self.packed_layout)

        # init from the same forward pass as the serial trainer
        ws = gcn.init_weights(cfg, jax.random.key(seed))
        a_full = graph.normalized_adjacency(g.num_nodes, g.edges)
        zs_full = gcn.forward(cfg, jnp.asarray(a_full),
                              jnp.asarray(g.features), ws)
        if packed:
            dl = self.packed_layout
            zs = tuple(jnp.asarray(dl.pack_state(
                self.layout.pack(np.asarray(z)))) for z in zs_full)
        else:
            zs = tuple(jnp.asarray(self.layout.pack(np.asarray(z)))
                       for z in zs_full)
        u = jnp.zeros_like(zs[-1])
        taus = tuple(jnp.asarray(admm.tau_init) for _ in ws)
        thetas = tuple(jnp.full((m,), admm.tau_init) for _ in zs)
        self.state = ParallelState(tuple(ws), zs, u, taus, thetas)

        self._plan = None
        ell_idx_dev = self.data.ell_indices
        if self.transport == "p2p":
            # bucketed layouts wire row-exact payloads: only each wired
            # community's true rows ever cross the wire; the global scheme
            # keeps the historic whole-n_pad-block messages.  Packed mode
            # additionally threads bucket row_counts so the plan carries
            # the plane routing tables (send/recv packed rows, offsets).
            self._plan = messages.build_neighbor_exchange(
                self.layout.neighbor_mask, n_shards, self.layout.n_pad,
                sizes=self.layout.sizes if pad_mode == "bucketed" else None,
                row_counts=self.layout.eff_row_counts() if packed else None)
            if n_shards == 1:
                # one shard hosts every community: nothing ever crosses the
                # wire, the transports are the same program (the all-gather
                # is a no-op collective), so keep the well-tested gather
                # body and only the p2p byte accounting (wire_bytes == 0)
                body_plan = None
            else:
                # ELL indices remapped host-side to receive-buffer slots —
                # the body never sees an (M, ...) payload
                body_plan = self._plan
                csr = self.layout.compress()
                ell_idx_dev = jnp.asarray(self._plan.localize_indices(
                    csr.ell_indices, csr.ell_mask))
        else:
            body_plan = None

        # static host tables for the packed body — captured in the partial
        # and indexed in-body by axis_index, so the shard_map specs never
        # see them (same pattern as the plan's send/recv tables)
        overlap_on = bool(overlap and body_plan is not None)
        packed_aux = None
        if packed:
            dl = self.packed_layout
            packed_aux = {
                "k": int(dl.lanes_per_shard),
                "n": int(dl.n_pad),
                "unpack": np.asarray(dl.unpack_rows),
                "pack": np.asarray(dl.pack_rows),
            }
            if body_plan is not None:
                csr = self.layout.compress()
                packed_aux["recv_unpack"] = \
                    np.asarray(self._plan.recv_unpack_rows)
                packed_aux["offsets"] = np.asarray(
                    self._plan.localized_offsets(
                        csr.ell_indices, csr.ell_mask)).reshape(
                    n_shards, dl.lanes_per_shard, -1)
                if overlap_on:
                    # host tables the per-step arrival-group computation
                    # needs: slot layout is plan-stable (restrict_exchange
                    # never touches buffer geometry), so the localized
                    # slots are computed once against the full plan
                    ov_loc = np.asarray(self._plan.localize_indices(
                        csr.ell_indices, csr.ell_mask)).reshape(
                        n_shards, dl.lanes_per_shard, -1)
                    ov_msk = np.asarray(csr.ell_mask).reshape(
                        n_shards, dl.lanes_per_shard, -1)

        sharded, rep = P(AXIS), P()
        n_l = cfg.num_layers
        if compressed:
            # each shard carries only its lanes' ELL rows — no dense
            # (M, M, n_pad, n_pad) tensor exists on device — plus its
            # lanes' ragged row counts for the kernel pad-row guards
            adj_data = (self.data.ell_blocks, ell_idx_dev,
                        self.data.ell_mask, self.data.row_counts,
                        self.data.nbr_counts)
            adj_spec = (sharded, sharded, sharded, sharded, sharded)
        else:
            adj_data = self.data.a_blocks
            adj_spec = sharded
        data = self.data
        k_lanes = m // n_shards

        def make_step(sampled=None):
            """Compile one ADMM step.  ``sampled`` (an iterable of shard
            ids) builds the stochastic-minibatch variant: the p2p round
            schedule is restricted to messages whose destination shard is
            sampled (messages.restrict_exchange — unsampled shards send
            their stale-but-exact rows, receive nothing), a static lane
            mask bakes the batch into the program, and a traced
            (M, max_deg) staleness weight rides along as the single extra
            input.  One program per distinct shard batch; the sampler's
            cycle structure bounds the program count by ``num_batches``."""
            if sampled is None:
                step_plan, mb_aux = body_plan, None
            else:
                sampled = frozenset(int(s) for s in sampled)
                step_plan = body_plan if body_plan is None else \
                    messages.restrict_exchange(body_plan, sampled)
                smask = np.zeros((n_shards, k_lanes), dtype=np.float32)
                smask[sorted(sampled)] = 1.0
                mb_aux = {"smask": smask}
            step_aux = packed_aux
            if overlap_on:
                # ELL slot -> arrival group of the *active* schedule:
                # 0 = resident own lanes (aggregable before any wire),
                # g = delivered by this plan's ppermute round g-1.  A
                # restricted sub-plan delivers fewer slots (and possibly
                # fewer rounds) than the full plan, so the table is
                # derived per compiled batch — slots the sub-schedule
                # never delivers fall into group 0, aggregate the
                # own-copy stage's zero rows, and only reach unsampled
                # lanes' iterates, which the smask gates freeze anyway.
                arr = messages.arrival_rounds(step_plan)
                grp = np.zeros_like(ov_loc)
                for s in range(n_shards):
                    grp[s] = np.where(ov_msk[s] != 0,
                                      arr[s][ov_loc[s]] + 1, 0)
                step_aux = dict(packed_aux, groups=grp)
            body = partial(_iteration_body, cfg, admm, use_kernel,
                           comm_bf16, compressed, step_plan, overlap_on,
                           fused, step_aux, mb_aux)
            in_specs = (adj_spec, sharded, sharded, sharded, sharded, rep,
                        (rep,) * n_l, (sharded,) * n_l, sharded,
                        (rep,) * n_l, (sharded,) * n_l)
            out_specs = ((rep,) * n_l, (sharded,) * n_l, sharded,
                         (rep,) * n_l, (sharded,) * n_l)
            if mb_aux is not None:
                in_specs = in_specs + (sharded,)
            mapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)

            # the state rebinds every step: donating it lets XLA reuse the
            # Z/U/weight buffers in place instead of doubling peak HBM
            # (memory/donated-inputs proves this holds on the compiled step)
            if mb_aux is None:
                @partial(jax.jit, donate_argnums=(0,))
                def step(state: ParallelState):
                    ws, zs, u, taus, thetas = mapped(
                        adj_data, data.neighbor_mask, data.z0, data.labels,
                        data.train_mask, data.denom, state.weights,
                        state.zs, state.u, state.taus, state.thetas)
                    return ParallelState(ws, zs, u, taus, thetas)
            else:
                @partial(jax.jit, donate_argnums=(0,))
                def step(state: ParallelState, nbr_decay):
                    ws, zs, u, taus, thetas = mapped(
                        adj_data, data.neighbor_mask, data.z0, data.labels,
                        data.train_mask, data.denom, state.weights,
                        state.zs, state.u, state.taus, state.thetas,
                        nbr_decay)
                    return ParallelState(ws, zs, u, taus, thetas)
            return step, step_plan

        self._make_step = make_step
        self._sampler = None
        self._round = 0
        if config.batch_fraction is None:
            self._step, _ = make_step(None)
            self._active_plan = self._plan
        else:
            # shard batch weights = Σ bucket rows hosted, so the greedy
            # balance targets resident/wire work, not shard count alone
            rc_shard = np.asarray(self.layout.eff_row_counts(),
                                  dtype=np.float64).reshape(
                n_shards, k_lanes).sum(axis=1)
            self._sampler = CommunityBatchSampler(
                n_shards, config.batch_fraction,
                seed=config.sample_seed, weights=rc_shard)
            csr_mb = self.layout.compress()
            self._mb_nbr = np.asarray(csr_mb.ell_indices)  # (M, D) global
            self._mb_k = k_lanes
            self._ages = np.zeros(m, dtype=np.int64)
            self._mb_steps = {}
            batch0 = frozenset(self._sampler.batch(0))
            self._mb_steps[batch0] = make_step(batch0)
            self._step, plan0 = self._mb_steps[batch0]
            self._active_plan = plan0 if plan0 is not None else self._plan

        # collective volume per iteration: the gathers the body issues are
        # one (M, n_pad, C) payload each for Z_0 (gathered exactly once per
        # step — it is static input), Z_1..Z_L, the relay aggregates q
        # (hidden layers), U, and the refreshed penultimate Z.  A 1-layer
        # net has no hidden Z loop (no q, no U gather) and its dual refresh
        # reuses the already-gathered Z_0.
        dims = list(cfg.layer_dims)
        gathered_cs = [dims[0]] + dims[1:]                # Z_0 (once), Z_1..Z_L
        if cfg.num_layers >= 2:
            gathered_cs += (dims[2:]                      # q per hidden layer
                            + [dims[-1], dims[-2]])       # U, Z_{L-1} refresh
        self.comm_stats = messages.gather_bytes(
            self.layout.neighbor_mask, self.layout.n_pad, gathered_cs,
            itemsize=2 if comm_bf16 else 4)
        self.comm_stats["transport"] = self.transport
        # residual-padding accounting: how many payload rows / aggregation
        # FLOPs this trainer spends beyond the true community sizes.  The
        # bucketed row_counts only shrink what a consumer actually
        # exploits, so each axis is gated on its consumer being engaged —
        # pad FLOPs drop only on the guarded-kernel path (use_kernel:
        # tiles past the row counts skip the DMA+accumulate on TPU; the
        # CPU/interpret fallbacks emulate the same masked semantics, so
        # off-TPU the number is the kernel-path bound rather than a
        # measured skip, while the default einsum body processes every
        # n_pad row and claims nothing), pad wire rows only under the
        # row-exact p2p transport (an all-gather moves full-pad payloads
        # regardless of layout) — the recorded numbers describe the
        # configured program, not the layout's potential
        self.comm_stats["pad_mode"] = pad_mode
        kernel_ragged = compressed and use_kernel
        wire_ragged = self.transport == "p2p"
        item = 2 if comm_bf16 else 4
        ps_flops = messages.pad_stats(
            self.layout.neighbor_mask, self.layout.sizes,
            self.layout.row_counts if kernel_ragged else None,
            self.layout.n_pad, gathered_cs, itemsize=item)
        ps_wire = messages.pad_stats(
            self.layout.neighbor_mask, self.layout.sizes,
            self.layout.row_counts if wire_ragged else None,
            self.layout.n_pad, gathered_cs, itemsize=item)
        self.comm_stats.update(ps_wire)
        self.comm_stats.update({k: ps_flops[k] for k in
                                ("pad_flops", "agg_flops", "pad_flop_frac")})
        self.comm_stats["pad_guards"] = {"kernel": kernel_ragged,
                                         "wire": wire_ragged}
        # the partition sets the communication: its edge cut is the p2p
        # wire volume's block count, its max_deg the ELL fan-in
        self.comm_stats["partitioner"] = self.partitioner
        self.comm_stats["partition"] = dict(self.partition_stats)
        if self._plan is not None:
            # scheduled p2p wire volume, tied to the mask-derived stats by
            # the transport invariant: wire == true rows + round padding
            # ≤ full, true rows ≤ needed (wire ≤ needed strictly at k=1)
            self.comm_stats.update(messages.exchange_bytes(
                self._plan, gathered_cs, itemsize=2 if comm_bf16 else 4))
            messages.verify_transport_bytes(self.comm_stats)
        else:
            # an all-gather moves every row to every shard
            self.comm_stats["wire_bytes"] = self.comm_stats["full_bytes"]
        # device-resident adjacency accounting for this trainer's mode
        # (itemsize-aware: the bf16 ELL block store halves the block term)
        self.comm_stats["adjacency"] = messages.adjacency_bytes(
            self.layout.neighbor_mask, self.layout.n_pad,
            itemsize=2 if adjacency_bf16 else 4)
        self.comm_stats["adjacency"]["resident_bytes"] = \
            int(self.data.adjacency_nbytes)

        # device-resident iterate accounting: the packed plane prices
        # Z/U/z0/labels/masks at Σ bucket rows (× the shard-max factor);
        # the strided layout at M·n_pad rows regardless of skew.  All
        # resident iterates are f32 (comm_bf16 compresses the wire only).
        z_cols = sum(dims[1:])                    # Z_1..Z_L feature columns
        state_cols = dims[0] + z_cols + dims[-1]  # + z0 + U
        rc_eff = np.asarray(self.layout.eff_row_counts(), dtype=np.int64)
        strided_rows = m * self.layout.n_pad
        resident_rows = self.packed_layout.total_rows if packed \
            else strided_rows
        self.comm_stats["state"] = {
            "packed": packed,
            "itemsize": 4,
            "rows": int(resident_rows),
            "strided_rows": int(strided_rows),
            "bucket_rows": int(rc_eff.sum()),
            "node_rows": int(np.asarray(self.layout.sizes).sum()),
            "z_bytes": int(resident_rows * z_cols * 4),
            "z_strided_bytes": int(strided_rows * z_cols * 4),
            "resident_bytes": int(resident_rows * (state_cols + 3) * 4),
            "strided_equiv_bytes": int(strided_rows * (state_cols + 3) * 4),
        }
        if self._plan is not None:
            # analytic overlap efficiency of the *active* round schedule —
            # consumed by benchmarks.roofline's exposed-wire pricing.
            # Under minibatching the compiled step runs the restricted
            # sub-plan, so that is what gets priced (the full plan would
            # overstate a sampled round's wire); ``step()`` re-prices when
            # the active batch changes.
            def _overlap_pricing(plan):
                return messages.overlap_stats(
                    plan, self.layout.neighbor_mask, gathered_cs,
                    itemsize=2 if comm_bf16 else 4, enabled=overlap_on)
            self._overlap_pricing = _overlap_pricing
            self.comm_stats["overlap"] = _overlap_pricing(self._active_plan)
        if self._sampler is None:
            self.comm_stats["minibatch"] = {"enabled": False}
        else:
            # sampled-round accounting over the first sampler cycle: every
            # batch's restricted schedule is priced with the same
            # exchange_bytes the full plan uses, so the wire ratio is an
            # apples-to-apples sub-plan/plan comparison
            cyc = self._sampler.cycle(0)
            wires, rows = [], []
            rc_sh = np.asarray(self.layout.eff_row_counts(),
                               dtype=np.int64).reshape(n_shards, k_lanes)
            for b in cyc:
                sub = self._plan if len(b) == n_shards else \
                    messages.restrict_exchange(self._plan, frozenset(b))
                wires.append(int(messages.exchange_bytes(
                    sub, gathered_cs, itemsize=item)["wire_bytes"]))
                rows.append(int(rc_sh[list(b)].sum()))
            self.comm_stats["minibatch"] = {
                "enabled": True,
                "batch_fraction": float(config.batch_fraction),
                "stale_decay": float(config.stale_decay),
                "sample_seed": int(config.sample_seed),
                "num_batches": int(self._sampler.num_batches),
                "schedule": [list(b) for b in cyc],
                "sampled_wire_bytes": wires[0],
                "mean_sampled_wire_bytes": float(np.mean(wires)),
                "full_wire_bytes": int(self.comm_stats["wire_bytes"]),
                "sampled_state_rows": rows[0],
                "mean_sampled_state_rows": float(np.mean(rows)),
                "full_state_rows": int(rc_sh.sum()),
            }

        # full-M packed aggregation for metrics/Lagrangian: ELL in compressed
        # mode (no dense adjacency is retained on device), masked dense
        # einsum otherwise
        if compressed:
            ell = (self.data.ell_blocks, self.data.ell_indices,
                   self.data.ell_mask)
            counts = (self.data.row_counts, self.data.nbr_counts)

            def agg_full(z_pack):
                from repro.kernels import ops as kops
                return kops.community_spmm_ell(*ell, z_pack, *counts)
        else:
            a_blocks = self.data.a_blocks
            nbr_f = self.data.neighbor_mask.astype(jnp.float32)

            def agg_full(z_pack):
                return jnp.einsum("mrip,rpc->mic",
                                  a_blocks * nbr_f[:, :, None, None], z_pack)

        data = self.data
        f_act = gcn.activation_fn(cfg.activation)

        # metrics/Lagrangian run on the blocked (M, n_pad, ...) view; in
        # packed mode the state planes are rebuilt through the device
        # layout's global row table (take-with-fill, bitwise lossless
        # under the zero-outside-counts contract)
        if packed:
            gup = jnp.asarray(self.packed_layout.global_unpack_rows())
            n_pad_loc = self.layout.n_pad

            def unfold(p):
                flat = jnp.take(p, gup, axis=0, mode="fill", fill_value=0)
                return flat.reshape((m, n_pad_loc) + p.shape[1:])
        else:
            def unfold(p):
                return p

        z0_blk = unfold(data.z0)
        labels_blk = unfold(data.labels)
        train_blk = unfold(data.train_mask)
        test_blk = unfold(data.test_mask)

        def forward_packed(weights):
            """Community-blocked forward pass — logits (M, n_pad, C_L)."""
            z = z0_blk
            for l, w in enumerate(weights):
                z = agg_full(z) @ w
                if l < cfg.num_layers - 1:
                    z = f_act(z)
            return z

        row_mask = data.row_mask[..., None]       # (M, n_pad, 1) true rows

        @jax.jit
        def metrics(state: ParallelState):
            logits = forward_packed(state.weights)
            z_pen = unfold(state.zs[-2]) if cfg.num_layers >= 2 else z0_blk
            res = (unfold(state.zs[-1]) - agg_full(z_pen)
                   @ state.weights[-1]) * row_mask
            return (gcn.accuracy(logits, labels_blk, train_blk),
                    gcn.accuracy(logits, labels_blk, test_blk),
                    jnp.linalg.norm(res))

        self._metrics = metrics

        @jax.jit
        def lagrangian(state: ParallelState):
            """ℒ_ρ(W, Z, U) — eq. (1) on the packed iterates.  Every
            residual is masked down to the true community rows
            (``row_mask``): pad slots carry zero adjacency/labels so the
            mask changes no value, it pins the invariant that padding —
            global or bucketed — never leaks into the objective, and the
            result equals the global subproblems.lagrangian_value on the
            unpacked state."""
            ws = state.weights
            zs = tuple(unfold(z) for z in state.zs)
            u = unfold(state.u)
            logp = jax.nn.log_softmax(zs[-1], axis=-1)
            nll = -jnp.take_along_axis(logp, labels_blk[..., None],
                                       axis=-1)[..., 0]
            val = jnp.sum(nll * train_blk) / data.denom
            z_prev = z0_blk
            for l in range(cfg.num_layers - 1):
                r = (zs[l] - f_act(agg_full(z_prev) @ ws[l])) * row_mask
                val += 0.5 * admm.nu * jnp.vdot(r, r).real
                z_prev = zs[l]
            r = (zs[-1] - agg_full(z_prev) @ ws[-1]) * row_mask
            val += jnp.vdot(u * row_mask, r).real \
                + 0.5 * admm.rho * jnp.vdot(r, r).real
            return val

        self._lagrangian = lagrangian

    def _nbr_decay(self):
        """Per-ELL-slot staleness weight d_r = stale_decay**age_r, looked
        up by the *global* neighbour community id (the body's localized
        indices never see community ids, so the table is built host-side
        and traced in as the step's one extra input)."""
        d = stale_weights(self._ages, self.config.stale_decay)
        return d[self._mb_nbr]                            # (M, max_deg)

    def _step_for(self, shards: frozenset):
        entry = self._mb_steps.get(shards)
        if entry is None:
            entry = self._make_step(shards)
            self._mb_steps[shards] = entry
        return entry

    @property
    def _analysis_args(self):
        """Arguments the compiled ``_step`` is lowered with (analysis)."""
        if self._sampler is None:
            return (self.state,)
        return (self.state, self._nbr_decay())

    def step(self) -> None:
        if self._sampler is None:
            self.state = self._step(self.state)
            return
        shards = frozenset(self._sampler.batch(self._round))
        step_fn, plan = self._step_for(shards)
        self._step = step_fn
        self._active_plan = plan if plan is not None else self._plan
        if "overlap" in self.comm_stats:
            # keep the overlap pricing tied to the plan this round runs
            self.comm_stats["overlap"] = self._overlap_pricing(
                self._active_plan)
        self.state = step_fn(self.state, self._nbr_decay())
        # ages advance after the round: a community sampled this round
        # ends it fresh (age 0 — "reset on resample"), everyone else's
        # consensus terms are one round staler
        self._ages += 1
        k = self._mb_k
        for s in shards:
            self._ages[s * k:(s + 1) * k] = 0
        self._round += 1
        mb = self.comm_stats["minibatch"]
        mb["rounds"] = self._round
        mb["last_batch"] = sorted(shards)
        mb["max_age"] = int(self._ages.max())

    def train(self, epochs: int, verbose: bool = False) -> "TrainLog":
        from repro.core.serial import TrainLog
        log = TrainLog()
        for epoch in range(epochs):
            t0 = time.perf_counter()
            self.step()
            jax.block_until_ready(self.state.zs[-1])
            dt = time.perf_counter() - t0
            tr, te, res = self._metrics(self.state)
            lag = self._lagrangian(self.state)
            log.epoch.append(epoch)
            log.train_acc.append(float(tr))
            log.test_acc.append(float(te))
            log.lagrangian.append(float(lag))
            log.residual.append(float(res))
            log.epoch_time_s.append(dt)
            if verbose:
                print(f"[parallel-admm] epoch {epoch:3d} train {tr:.3f} "
                      f"test {te:.3f} lagr {lag:.4f} res {res:.2e} "
                      f"({dt*1e3:.1f} ms)")
        return log
