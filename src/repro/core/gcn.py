"""GCN model (Kipf & Welling) in the paper's notation.

``Z_l = f_l(Ã Z_{l-1} W_l)`` for l < L and ``Z_L = Ã Z_{L-1} W_L`` (logits).
Used both by the ADMM trainers (as the constraint functions) and by the
SGD-family baselines (plain backprop training, §4.2 comparison methods).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    layer_dims: tuple[int, ...]   # (C_0, C_1, ..., C_L)
    activation: str = "relu"      # f_l for l < L

    @property
    def num_layers(self) -> int:
        return len(self.layer_dims) - 1


def activation_fn(name: str) -> Callable[[Array], Array]:
    return {"relu": jax.nn.relu, "tanh": jnp.tanh,
            "identity": lambda x: x}[name]


def init_weights(cfg: GCNConfig, key: jax.Array) -> list[Array]:
    """Glorot init, one W_l per layer."""
    ws = []
    for l in range(cfg.num_layers):
        key, sub = jax.random.split(key)
        fan_in, fan_out = cfg.layer_dims[l], cfg.layer_dims[l + 1]
        scale = jnp.sqrt(2.0 / (fan_in + fan_out))
        ws.append(scale * jax.random.normal(sub, (fan_in, fan_out),
                                            dtype=jnp.float32))
    return ws


def forward(cfg: GCNConfig, a_tilde: Array, z0: Array,
            weights: Sequence[Array]) -> list[Array]:
    """Full forward pass; returns [Z_1, ..., Z_L] (Z_L = logits)."""
    f = activation_fn(cfg.activation)
    zs = []
    z = z0
    num_layers = cfg.num_layers
    for l, w in enumerate(weights):
        z = a_tilde @ z @ w
        if l < num_layers - 1:
            z = f(z)
        zs.append(z)
    return zs


def masked_cross_entropy(logits: Array, labels: Array, mask: Array) -> Array:
    """R(Z_L, Y): mean cross-entropy over masked (labeled) nodes."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1)
    return jnp.sum(nll * mask) / denom


def accuracy(logits: Array, labels: Array, mask: Array) -> Array:
    pred = jnp.argmax(logits, axis=-1)
    hits = (pred == labels) * mask
    return hits.sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(cfg: GCNConfig, a_tilde: Array, z0: Array,
            weights: Sequence[Array], labels: Array, mask: Array) -> Array:
    logits = forward(cfg, a_tilde, z0, weights)[-1]
    return masked_cross_entropy(logits, labels, mask)
