"""Layerwise ADMM for transformer stacks — the paper's technique beyond GCN.

The GCN trainer splits *graph nodes* into communities and *layers* into
independent ADMM blocks.  For the assigned architectures the same two axes
map onto the mesh (DESIGN.md §3):

  * layer splitting  -> the stacked layer axis (L, ...) of every segment is
    sharded over the ``model`` mesh axis.  All W_b and Z_b subproblems are
    data-local to their shard; the ONLY inter-block communication is the
    shifted activation Z_{b-1}, a collective-permute along ``model`` — a
    bubble-free "pipeline" which is exactly Algorithm 1's layer parallelism.
  * community splitting -> the batch/token axis shards over ``data``
    (sequences are the "communities"; with full attention inside a block
    there is no cross-shard halo, so the Z subproblems are embarrassingly
    parallel over data — the GCN's p/s messages have no analogue here and
    communication drops out entirely).

Subproblems mirror subproblems.py: quadratic-approximation steps with
per-(segment, block) backtracking (lane-masked over the stacked layer dim),
FISTA for the head/readout, dual ascent on the last constraint.

Scope: trains the stack weights W (all segments) + readout by ADMM on a
fixed batch (the paper's full-batch regime).  Embedding inputs Z_0 are the
(frozen-embedding) features, as in the paper where Z_0 is the input matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.subproblems import ADMMConfig
from repro.models import layers as L
from repro.models import transformer
from repro.models.build import Model, _next_token_ce

Array = jax.Array


class LayerwiseState(NamedTuple):
    stack: Any                 # stacked per-segment weights (as Model)
    readout: Any               # final_norm + unembed params
    zs: dict                   # segment -> (n_layers, B, S, D) activations
    u: Array                   # dual for the last constraint (B, S, D)
    taus: dict                 # segment -> (n_layers,) curvatures for W
    thetas: dict               # segment -> (n_layers,) curvatures for Z
    tau_r: Array               # readout curvature


def _tree_lane_norm_sq(tree, lanes: int):
    """Per-lane squared norms over a pytree with leading lane dim."""
    total = jnp.zeros((lanes,), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        total += jnp.sum(
            jnp.square(leaf.astype(jnp.float32)).reshape(lanes, -1), axis=1)
    return total


def lane_backtracking_tree(obj_lanes: Callable, x, theta0: Array,
                           admm: ADMMConfig):
    """Per-lane majorize-minimize step on a PYTREE with leading lane dim.

    obj_lanes(x) -> (lanes,).  Lanes accept independently (paper's per-block
    τ_l / per-community θ_{l,m}); frozen lanes stop doubling.
    """
    lanes = theta0.shape[0]
    vals = obj_lanes(x)
    grads = jax.grad(lambda t: obj_lanes(t).sum())(x)
    g_sq = _tree_lane_norm_sq(grads, lanes)

    def step(theta):
        inv = 1.0 / theta
        return jax.tree.map(
            lambda xx, gg: (xx.astype(jnp.float32)
                            - gg.astype(jnp.float32)
                            * inv.reshape((lanes,) + (1,) * (gg.ndim - 1))
                            ).astype(xx.dtype), x, grads)

    def accepted(theta):
        bound = vals - 0.5 * g_sq / theta
        tol = admm.backtrack_rtol * (jnp.abs(bound) + 1e-12)
        return obj_lanes(step(theta)) <= bound + tol

    def cond(carry):
        theta, done, it = carry
        return (~jnp.all(done)) & (it < admm.max_backtracks)

    def body(carry):
        theta, done, it = carry
        theta = jnp.where(done, theta, theta * admm.backtrack_growth)
        done = done | accepted(theta)
        return theta, done, it + 1

    theta0 = jnp.maximum(theta0 / admm.backtrack_growth, 1e-8)
    theta, _, _ = jax.lax.while_loop(cond, body,
                                     (theta0, accepted(theta0),
                                      jnp.asarray(0)))
    return step(theta), theta


@dataclasses.dataclass
class LayerwiseADMMTrainer:
    """Blockwise-ADMM training of a transformer on a fixed batch."""

    cfg: ModelConfig
    admm: ADMMConfig
    mesh: Mesh | None = None

    def __post_init__(self):
        self.cfg = dataclasses.replace(self.cfg, remat=False)
        self.model = Model(self.cfg)
        self.segments = [s for s in transformer.arch_segments(self.cfg)
                         if s.kind != "enc"]

    # -------------------------------------------------------------- helpers

    def _constraint_spec(self):
        """Sharding: blocks over 'model', batch over 'data'."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P("model", "data", None, None))

    def _shard_z(self, z):
        spec = self._constraint_spec()
        return z if spec is None else jax.lax.with_sharding_constraint(z, spec)

    def _apply_blocks(self, kind: str, stacked_w, inputs):
        """vmap a single block over the stacked layer axis: F_b(Z_{b-1})."""
        if inputs.shape[0] == 0:
            # empty block stack (e.g. the within-segment coupling of a
            # single-block segment) — vmap over a size-0 axis crashes some
            # batching rules (lax.top_k in the MoE router), so short-circuit
            return jnp.zeros_like(inputs)

        def one(w, x):
            out, _ = transformer.apply_layer(self.cfg, kind, w, x)
            return out
        return jax.vmap(one)(stacked_w, inputs)

    def _shifted_inputs(self, z0: Array, zs: Array) -> Array:
        """[Z_0, Z_1, ..., Z_{L-1}]: one collective-permute along 'model'."""
        return jnp.concatenate([z0[None], zs[:-1]], axis=0)

    def _readout_logits(self, readout, z_last):
        h = L.apply_norm(self.cfg, readout["final_norm"], z_last)
        return L.unembed(self.cfg, readout["embedding"], h)

    # ----------------------------------------------------------------- init

    def init(self, key, batch: dict) -> LayerwiseState:
        params = self.model.init(key)
        z0 = self.model._embed_inputs(params, batch)
        zs, taus, thetas = {}, {}, {}
        x = z0
        for seg in self.segments:
            stacked = params["stack"][seg.kind]
            outs = []
            for b in range(seg.count):
                w_b = jax.tree.map(lambda l, b=b: l[b], stacked)
                x, _ = transformer.apply_layer(self.cfg, seg.kind, w_b, x)
                outs.append(x)
            zs[seg.kind] = self._shard_z(jnp.stack(outs, axis=0))
            taus[seg.kind] = jnp.full((seg.count,), self.admm.tau_init)
            thetas[seg.kind] = jnp.full((seg.count,), self.admm.tau_init)
        readout = {"final_norm": params["final_norm"],
                   "embedding": params["embedding"]}
        u = jnp.zeros_like(zs[self.segments[-1].kind][-1],
                           dtype=jnp.float32)
        return LayerwiseState(params["stack"], readout, zs, u, taus, thetas,
                              jnp.asarray(self.admm.tau_init)), z0

    # ------------------------------------------------------------ iteration

    def iteration(self, state: LayerwiseState, z0: Array,
                  targets: Array) -> LayerwiseState:
        admm, cfg = self.admm, self.cfg
        segs = self.segments
        last_kind = segs[-1].kind

        # ---- W update: all blocks of all segments in parallel (Jacobi) ----
        new_stack, new_taus = {}, {}
        seg_in = z0
        for seg in segs:
            zsk = state.zs[seg.kind]
            inputs = self._shifted_inputs(seg_in, zsk)
            is_last_seg = seg.kind == last_kind

            def w_obj(stacked_w, zsk=zsk, inputs=inputs, seg=seg,
                      is_last=is_last_seg):
                pred = self._apply_blocks(seg.kind, stacked_w, inputs)
                r = (zsk - pred).astype(jnp.float32)
                vals = 0.5 * admm.nu * jnp.sum(
                    r * r, axis=tuple(range(1, r.ndim)))
                if is_last:
                    # last block carries the augmented-Lagrangian terms
                    r_last = r[-1]
                    lin = jnp.sum(state.u * r_last)
                    quad = 0.5 * (admm.rho - admm.nu) * jnp.sum(
                        r_last * r_last)
                    vals = vals.at[-1].add(lin + quad)
                return vals

            new_w, tau = lane_backtracking_tree(
                w_obj, state.stack[seg.kind], state.taus[seg.kind], admm)
            new_stack[seg.kind] = new_w
            new_taus[seg.kind] = tau
            seg_in = zsk[-1]

        # ---- readout update (R's own parameters, gradient step) ----
        z_last = state.zs[last_kind][-1]

        def r_obj(readout):
            return _next_token_ce(self._readout_logits(readout, z_last),
                                  targets)

        (new_readout, tau_r) = lane_backtracking_tree(
            lambda ro: r_obj(ro)[None],
            state.readout, state.tau_r[None], admm)
        tau_r = tau_r[0]

        # ---- Z update: all blocks in parallel (reads W^{k+1}, Z^k) ----
        new_zs, new_thetas = {}, {}
        seg_in = z0
        for si, seg in enumerate(segs):
            zsk = state.zs[seg.kind]
            w_new = new_stack[seg.kind]
            inputs = self._shifted_inputs(seg_in, zsk)
            targets_blocks = self._apply_blocks(seg.kind, w_new, inputs)
            is_last_seg = seg.kind == last_kind

            # cross-segment coupling: the last block of segment si feeds the
            # FIRST block of segment si+1 — F_{si+1,0}(Z_{si,last}) vs
            # Z_{si+1,0}^k.  When that next block is the network's final
            # block, this edge is the dualized constraint and carries the
            # augmented-Lagrangian terms (otherwise the u update would have
            # no consumer for single-block last segments).
            if not is_last_seg:
                nseg = segs[si + 1]
                w_x0 = jax.tree.map(lambda l: l[0], new_stack[nseg.kind])
                z_x_ref = state.zs[nseg.kind][0]
                x_is_final = nseg.kind == last_kind and nseg.count == 1
            else:
                nseg = w_x0 = z_x_ref = None
                x_is_final = False

            def z_obj(zsk_var, targets_blocks=targets_blocks, seg=seg,
                      w_new=w_new, zsk=zsk, is_last=is_last_seg,
                      nseg=nseg, w_x0=w_x0, z_x_ref=z_x_ref,
                      x_is_final=x_is_final):
                r1 = (zsk_var - targets_blocks).astype(jnp.float32)
                vals = 0.5 * admm.nu * jnp.sum(
                    r1 * r1, axis=tuple(range(1, r1.ndim)))
                # coupling: blocks 0..L-2 feed block b+1 (within segment)
                w_next = jax.tree.map(lambda l: l[1:], w_new)
                pred_next = self._apply_blocks(seg.kind, w_next,
                                               zsk_var[:-1])
                r2 = (zsk[1:] - pred_next).astype(jnp.float32)
                v2 = 0.5 * admm.nu * jnp.sum(
                    r2 * r2, axis=tuple(range(1, r2.ndim)))
                if is_last and v2.shape[0]:
                    r2_last = r2[-1]
                    lin = jnp.sum(state.u * r2_last)
                    quad = 0.5 * (admm.rho - admm.nu) * jnp.sum(
                        r2_last * r2_last)
                    v2 = v2.at[-1].add(lin + quad)
                vals = vals.at[:-1].add(v2)
                # coupling across the segment boundary (last lane)
                if nseg is not None:
                    pred_x, _ = transformer.apply_layer(
                        cfg, nseg.kind, w_x0, zsk_var[-1])
                    r2x = (z_x_ref - pred_x).astype(jnp.float32)
                    vx = 0.5 * admm.nu * jnp.sum(r2x * r2x)
                    if x_is_final:
                        vx = vx + jnp.sum(state.u * r2x) + \
                            0.5 * (admm.rho - admm.nu) * jnp.sum(r2x * r2x)
                    vals = vals.at[-1].add(vx)
                # last block of last segment: CE readout term
                if is_last:
                    ce = _next_token_ce(
                        self._readout_logits(new_readout, zsk_var[-1]),
                        targets)
                    vals = vals.at[-1].add(ce)
                return vals

            z_new, theta = lane_backtracking_tree(
                z_obj, zsk, state.thetas[seg.kind], admm)
            new_zs[seg.kind] = self._shard_z(z_new)
            new_thetas[seg.kind] = theta
            seg_in = zsk[-1]

        # ---- dual ascent on the last constraint ----
        seg = segs[-1]
        zsk_new = new_zs[seg.kind]
        prev_in = z0 if len(segs) == 1 and seg.count == 1 else (
            zsk_new[-2] if seg.count > 1 else new_zs[segs[-2].kind][-1])
        w_last = jax.tree.map(lambda l: l[-1], new_stack[seg.kind])
        pred_last, _ = transformer.apply_layer(cfg, seg.kind, w_last,
                                               prev_in)
        residual = (zsk_new[-1] - pred_last).astype(jnp.float32)
        new_u = state.u + admm.rho * residual

        return LayerwiseState(new_stack, new_readout, new_zs, new_u,
                              new_taus, new_thetas, tau_r)

    # ---------------------------------------------------------------- train

    def metrics(self, state: LayerwiseState, z0: Array, targets: Array):
        """CE of the *composed* network (no auxiliary Z) + residual norm."""
        x = z0
        for seg in self.segments:
            def body(carry, w):
                out, _ = transformer.apply_layer(self.cfg, seg.kind, w,
                                                 carry)
                return out, None
            x, _ = jax.lax.scan(body, x, state.stack[seg.kind])
        ce = _next_token_ce(self._readout_logits(state.readout, x), targets)
        last = self.segments[-1].kind
        res = jnp.linalg.norm(
            (state.zs[last][-1] - x).astype(jnp.float32)) / \
            jnp.sqrt(jnp.asarray(x.size, jnp.float32))
        return ce, res
