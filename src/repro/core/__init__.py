"""Core: the paper's contribution — community-based layerwise ADMM training.

- graph:        Ã construction, community partitioner, blocked layout
- gcn:          the GCN model in the paper's notation
- subproblems:  W/Z/U ADMM updates (global form), backtracking, FISTA
- messages:     first/second-order community messages (Appendix A, eq. 4)
- serial:       the paper's Serial ADMM trainer + SGD-family baselines
- parallel:     the paper's Parallel ADMM trainer (shard_map over agents)
- layerwise:    the technique generalized to transformer stacks (beyond-GCN)
"""
from repro.core.gcn import GCNConfig  # noqa: F401
from repro.core.subproblems import ADMMConfig  # noqa: F401
