"""ADMM subproblem solvers (paper §3 + Appendix A), global (full-graph) form.

All updates are Jacobi-style exactly as in Algorithm 1: every ``W_l`` update
reads ``Z^k`` (parallel over l), every ``Z_l`` update reads ``W^{k+1}`` and
``Z^k`` (parallel over l and m), then the dual ``U`` ascends.

The quadratic-approximation (majorize-minimize) step of eq. (2)/(8) is
implemented with backtracking on the curvature parameter (τ for W, θ for Z):
double τ until ``P(x_new; τ) ≥ φ(x_new)`` — the paper's condition — which is
the standard descent-lemma test.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import gcn

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    nu: float = 1e-3        # ν — penalty on intermediate-layer constraints
    rho: float = 1e-3       # ρ — augmented-Lagrangian penalty, last layer
    tau_init: float = 1.0   # initial curvature for backtracking
    backtrack_growth: float = 2.0
    max_backtracks: int = 30
    fista_iters: int = 8    # inner FISTA iterations for the Z_L prox problem
    # relative acceptance slack: P(x⁺;τ) ≥ φ(x⁺) − tol·|φ| guards against
    # reduction-order float noise when ∇φ ≈ 0 (exact ties at initialization)
    backtrack_rtol: float = 1e-6


class ADMMState(NamedTuple):
    weights: tuple[Array, ...]   # W_1..W_L
    zs: tuple[Array, ...]        # Z_1..Z_L (auxiliary activations)
    u: Array                     # U — dual for the Z_L constraint
    taus: tuple[Array, ...]      # warm-started τ_l
    thetas: tuple[Array, ...]    # warm-started θ_l


def init_state(cfg: gcn.GCNConfig, admm: ADMMConfig, a_tilde: Array,
               z0: Array, key: jax.Array) -> ADMMState:
    ws = gcn.init_weights(cfg, key)
    zs = gcn.forward(cfg, a_tilde, z0, ws)
    u = jnp.zeros_like(zs[-1])
    taus = tuple(jnp.asarray(admm.tau_init) for _ in ws)
    thetas = tuple(jnp.asarray(admm.tau_init) for _ in zs)
    return ADMMState(tuple(ws), tuple(zs), u, taus, thetas)


# ---------------------------------------------------------------------------
# φ objectives (paper §3 definitions)
# ---------------------------------------------------------------------------

def phi_hidden(admm: ADMMConfig, f: Callable, a_tilde: Array, w: Array,
               z_prev: Array, z: Array) -> Array:
    """φ(W_l, Z_{l-1}, Z_l) = ν/2 ‖Z_l − f(Ã Z_{l-1} W_l)‖²  (l < L)."""
    r = z - f(a_tilde @ z_prev @ w)
    return 0.5 * admm.nu * jnp.vdot(r, r).real


def phi_last(admm: ADMMConfig, a_tilde: Array, w: Array, z_prev: Array,
             z: Array, u: Array) -> Array:
    """φ(W_L, Z_{L-1}, Z_L, U) = ⟨U, Z_L − ÃZ_{L-1}W_L⟩ + ρ/2‖·‖²."""
    r = z - a_tilde @ z_prev @ w
    return jnp.vdot(u, r).real + 0.5 * admm.rho * jnp.vdot(r, r).real


# ---------------------------------------------------------------------------
# Quadratic-approximation backtracking step (eq. 2 / eq. 8-10)
# ---------------------------------------------------------------------------

def backtracking_step(obj: Callable[[Array], Array], x: Array, tau0: Array,
                      admm: ADMMConfig) -> tuple[Array, Array]:
    """One majorize-minimize step: x⁺ = x − ∇obj(x)/τ with τ doubled until
    P(x⁺; τ) ≥ obj(x⁺).  Returns (x⁺, accepted τ)."""
    val, grad = jax.value_and_grad(obj)(x)
    g_sq = jnp.vdot(grad, grad).real

    def candidate(tau):
        x_new = x - grad / tau
        # P(x_new; τ) = val + <g, Δ> + τ/2‖Δ‖², Δ = −g/τ  ⇒ val − ‖g‖²/(2τ)
        p_val = val - 0.5 * g_sq / tau
        return x_new, p_val

    def cond(carry):
        tau, it = carry
        x_new, p_val = candidate(tau)
        tol = admm.backtrack_rtol * (jnp.abs(p_val) + 1e-12)
        return (p_val + tol < obj(x_new)) & (it < admm.max_backtracks)

    def body(carry):
        tau, it = carry
        return tau * admm.backtrack_growth, it + 1

    # warm start slightly optimistically (shrink), then grow to acceptance
    tau0 = jnp.maximum(tau0 / admm.backtrack_growth, 1e-8)
    tau, _ = jax.lax.while_loop(cond, body, (tau0, jnp.asarray(0)))
    x_new, _ = candidate(tau)
    return x_new, tau


def stale_weights(ages: Array, stale_decay: float) -> Array:
    """Staleness-decayed penalty weights for stochastic community batches.

    Under minibatched ADMM (parallel trainer, ``batch_fraction`` < 1) the
    communities left out of a round keep their Z/U at the last written
    iterate — exact values, merely ``age`` rounds old.  The sampled
    communities' coupling terms to a neighbour r are down-weighted by

        d_r = stale_decay ** age_r                       (d_r ∈ (0, 1])

    i.e. the effective penalties become ν·d_r and ρ·d_r and the dual term
    ⟨U_r, ·⟩ scales by d_r — a damped augmented Lagrangian that trusts a
    neighbour's constraint residual less the longer its iterate has been
    frozen.  ``age_r`` resets to 0 on resample, restoring full weight.

    Two exactness anchors the trainer's parity tests pin:
      * age 0 ⇒ d = 1.0 *bitwise* (IEEE pow(x, 0) == 1.0), so a full
        batch (every age 0) reproduces the undamped objective exactly;
      * stale_decay = 1.0 ⇒ d = 1.0 for every age — sampling degrades to
        exact block-coordinate descent with undamped couplings.
    """
    base = jnp.asarray(stale_decay, dtype=jnp.float32)
    return jnp.power(base, jnp.asarray(ages).astype(jnp.float32))


# ---------------------------------------------------------------------------
# ψ objectives for Z updates (Appendix A, global form)
# ---------------------------------------------------------------------------

def make_psi(cfg: gcn.GCNConfig, admm: ADMMConfig, a_tilde: Array, z0: Array,
             weights: Sequence[Array], zs: Sequence[Array], u: Array,
             l: int) -> Callable[[Array], Array]:
    """Objective for Z_l (1-indexed layer l = idx+1), l < L.  Eq. (5)/(6)."""
    f = gcn.activation_fn(cfg.activation)
    num_layers = cfg.num_layers
    z_below = z0 if l == 1 else zs[l - 2]
    w_l, w_next = weights[l - 1], weights[l]

    def psi(z):
        # self-reconstruction term (this layer's constraint)
        r1 = z - f(a_tilde @ z_below @ w_l)
        val = 0.5 * admm.nu * jnp.vdot(r1, r1).real
        if l + 1 < num_layers:            # eq. (5): next layer is hidden
            r2 = zs[l] - f(a_tilde @ z @ w_next)
            val += 0.5 * admm.nu * jnp.vdot(r2, r2).real
        else:                             # eq. (6): next layer is the last
            r2 = zs[num_layers - 1] - a_tilde @ z @ w_next
            val += jnp.vdot(u, r2).real + 0.5 * admm.rho * jnp.vdot(r2, r2).real
        return val

    return psi


def fista_last_z(admm: ADMMConfig, b: Array, u: Array, labels: Array,
                 mask: Array, z_init: Array,
                 denom: Array | None = None) -> Array:
    """Solve eq. (7): argmin_Z R(Z,Y) + ⟨U, Z−B⟩ + ρ/2‖Z−B‖² with FISTA [1].

    The objective is smooth, so FISTA reduces to Nesterov-accelerated
    gradient with per-iteration Lipschitz backtracking.  ``denom`` overrides
    the CE normalizer (the parallel trainer passes the *global* labeled count
    so per-community subproblems sum to the global objective).
    """

    def obj(z):
        r = z - b
        if denom is None:
            ce = gcn.masked_cross_entropy(z, labels, mask)
        else:
            logp = jax.nn.log_softmax(z, axis=-1)
            nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
            ce = jnp.sum(nll * mask) / denom
        return (ce + jnp.vdot(u, r).real
                + 0.5 * admm.rho * jnp.vdot(r, r).real)

    grad_fn = jax.grad(obj)

    def step(carry, _):
        z, y, t, lip = carry
        val_y = obj(y)
        g = grad_fn(y)
        g_sq = jnp.vdot(g, g).real

        def bt_cond(state):
            lip, it = state
            z_new = y - g / lip
            # descent lemma test: obj(z_new) ≤ obj(y) − ‖g‖²/(2L) (+ rtol)
            bound = val_y - 0.5 * g_sq / lip
            tol = admm.backtrack_rtol * (jnp.abs(bound) + 1e-12)
            return (obj(z_new) > bound + tol) & (it < admm.max_backtracks)

        def bt_body(state):
            lip, it = state
            return lip * admm.backtrack_growth, it + 1

        lip, _ = jax.lax.while_loop(bt_cond, bt_body, (lip, jnp.asarray(0)))
        z_new = y - g / lip
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = z_new + ((t - 1.0) / t_new) * (z_new - z)
        return (z_new, y_new, t_new, lip * 0.9), None

    init = (z_init, z_init, jnp.asarray(1.0), jnp.asarray(admm.rho + 1.0))
    (z, _, _, _), _ = jax.lax.scan(step, init, None, length=admm.fista_iters)
    return z


# ---------------------------------------------------------------------------
# One full ADMM iteration (Algorithm 1), global form
# ---------------------------------------------------------------------------

def admm_iteration(cfg: gcn.GCNConfig, admm: ADMMConfig, a_tilde: Array,
                   z0: Array, labels: Array, mask: Array,
                   state: ADMMState) -> ADMMState:
    f = gcn.activation_fn(cfg.activation)
    num_layers = cfg.num_layers
    ws, zs, u, taus, thetas = state

    # ---- Line 3: update W_l for all l in parallel (Jacobi, reads Z^k) ----
    new_ws, new_taus = [], []
    for l in range(num_layers):
        z_prev = z0 if l == 0 else zs[l - 1]
        if l < num_layers - 1:
            def obj(w, zp=z_prev, z=zs[l]):
                return phi_hidden(admm, f, a_tilde, w, zp, z)
        else:
            def obj(w, zp=z_prev, z=zs[l]):
                return phi_last(admm, a_tilde, w, zp, z, u)
        w_new, tau = backtracking_step(obj, ws[l], taus[l], admm)
        new_ws.append(w_new)
        new_taus.append(tau)
    new_ws = tuple(new_ws)

    # ---- Line 4: update Z_{l} for all l in parallel (reads W^{k+1}, Z^k) --
    new_zs, new_thetas = [], []
    for l in range(1, num_layers):          # hidden layers: eq. (8)-(10)
        psi = make_psi(cfg, admm, a_tilde, z0, new_ws, zs, u, l)
        z_new, theta = backtracking_step(psi, zs[l - 1], thetas[l - 1], admm)
        new_zs.append(z_new)
        new_thetas.append(theta)
    # last layer: FISTA prox (eq. 7)
    z_pen = zs[num_layers - 2] if num_layers >= 2 else z0
    b = a_tilde @ z_pen @ new_ws[-1]
    z_last = fista_last_z(admm, b, u, labels, mask, zs[-1])
    new_zs.append(z_last)
    new_thetas.append(thetas[-1])
    new_zs = tuple(new_zs)

    # ---- Line 5: dual ascent (eq. 3) ----
    z_pen_new = new_zs[num_layers - 2] if num_layers >= 2 else z0
    residual = new_zs[-1] - a_tilde @ z_pen_new @ new_ws[-1]
    new_u = u + admm.rho * residual

    return ADMMState(new_ws, new_zs, new_u, tuple(new_taus), tuple(new_thetas))


def lagrangian_value(cfg: gcn.GCNConfig, admm: ADMMConfig, a_tilde: Array,
                     z0: Array, labels: Array, mask: Array,
                     state: ADMMState) -> Array:
    """ℒ_ρ(W, Z, U) — eq. (1), for convergence monitoring."""
    f = gcn.activation_fn(cfg.activation)
    ws, zs, u = state.weights, state.zs, state.u
    val = gcn.masked_cross_entropy(zs[-1], labels, mask)
    z_prev = z0
    for l in range(cfg.num_layers - 1):
        r = zs[l] - f(a_tilde @ z_prev @ ws[l])
        val += 0.5 * admm.nu * jnp.vdot(r, r).real
        z_prev = zs[l]
    r = zs[-1] - a_tilde @ z_prev @ ws[-1]
    val += jnp.vdot(u, r).real + 0.5 * admm.rho * jnp.vdot(r, r).real
    return val
