"""Graph substrate for the community-based ADMM GCN trainer.

Host-side (numpy) utilities: normalized adjacency construction, balanced
community partitioning (METIS stand-in, same contract), community-blocked
dense layout used by the shard_map parallel trainer and the Pallas
``community_spmm`` kernel.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected, unweighted graph with node features and labels."""

    edges: Array          # (E, 2) int32, undirected (each edge stored once)
    features: Array       # (N, C0) float32
    labels: Array         # (N,) int32
    train_mask: Array     # (N,) bool
    test_mask: Array      # (N,) bool
    num_classes: int

    @property
    def num_nodes(self) -> int:
        return int(self.features.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])


def adjacency_lists(num_nodes: int, edges: Array) -> list[list[int]]:
    adj: list[list[int]] = [[] for _ in range(num_nodes)]
    for u, v in edges:
        if u != v:
            adj[int(u)].append(int(v))
            adj[int(v)].append(int(u))
    return adj


def normalized_adjacency(num_nodes: int, edges: Array) -> Array:
    """Dense Ã = (D+I)^{-1/2} (A+I) (D+I)^{-1/2} (paper, Problem 1)."""
    a = np.zeros((num_nodes, num_nodes), dtype=np.float32)
    u, v = edges[:, 0], edges[:, 1]
    a[u, v] = 1.0
    a[v, u] = 1.0
    np.fill_diagonal(a, 0.0)
    deg = a.sum(axis=1)
    a = a + np.eye(num_nodes, dtype=np.float32)
    d_inv_sqrt = 1.0 / np.sqrt(deg + 1.0)
    return (a * d_inv_sqrt[:, None]) * d_inv_sqrt[None, :]


def partition_graph(num_nodes: int, edges: Array, num_parts: int,
                    seed: int = 0, refine_iters: int = 4,
                    method: str = "bfs_kl") -> Array:
    """Balanced edge-cut-minimizing partition.  Returns (N,) int32 ids.

    Two methods share the contract (every node assigned exactly once,
    part sizes ≤ ceil(N / num_parts), deterministic for a fixed seed):

      * ``"bfs_kl"`` (default, the original METIS stand-in): BFS-grown
        balanced seeds followed by Kernighan-Lin-style boundary refinement
        under a hard balance cap.  Kept as the oracle/fallback — its
        partitions are golden-checksummed in tests.
      * ``"multilevel"`` (sharding.multilevel): heavy-edge-matching
        coarsening → initial partition of the coarse graph → uncoarsen
        with boundary KL refinement at every level, the METIS scheme.
        Strictly lower edge cuts on power-law community graphs — the cut
        is the p2p wire volume, see BENCH_speedup.json `m32_partition`.
    """
    if method == "multilevel":
        from repro.sharding.multilevel import multilevel_partition
        return multilevel_partition(num_nodes, edges, num_parts, seed=seed,
                                    refine_iters=refine_iters)
    if method != "bfs_kl":
        raise ValueError(f"unknown partition method {method!r}; "
                         f"expected 'bfs_kl' or 'multilevel'")
    rng = np.random.default_rng(seed)
    adj = adjacency_lists(num_nodes, edges)
    cap = int(np.ceil(num_nodes / num_parts))
    part = np.full(num_nodes, -1, dtype=np.int32)
    sizes = np.zeros(num_parts, dtype=np.int64)

    # BFS-grow each partition from a fresh unassigned seed.
    order = rng.permutation(num_nodes)
    cursor = 0
    for p in range(num_parts):
        # find an unassigned seed
        while cursor < num_nodes and part[order[cursor]] >= 0:
            cursor += 1
        if cursor >= num_nodes:
            break
        # deque + enqueue-time seen marking: O(N + E) per part.  A node is
        # processed at its *earliest* enqueue position either way, so the
        # assignment order (and hence the partition for a fixed seed) is
        # identical to the old list.pop(0)/re-enqueue implementation, which
        # was O(N·frontier) and enqueued each neighbour once per discovery.
        seed_node = int(order[cursor])
        frontier = collections.deque([seed_node])
        seen = {seed_node}
        while frontier and sizes[p] < cap:
            node = frontier.popleft()
            part[node] = p
            sizes[p] += 1
            for n in adj[node]:
                if part[n] < 0 and n not in seen:
                    seen.add(n)
                    frontier.append(n)
    # Any stragglers go to the least-loaded part.
    for node in np.flatnonzero(part < 0):
        p = int(np.argmin(sizes))
        part[node] = p
        sizes[p] += 1

    # KL-style refinement: move boundary nodes if it reduces the cut and
    # keeps balance.
    for _ in range(refine_iters):
        moved = 0
        for node in rng.permutation(num_nodes):
            if not adj[node]:
                continue
            counts = np.bincount([part[n] for n in adj[node]],
                                 minlength=num_parts)
            best = int(np.argmax(counts))
            cur = int(part[node])
            if best != cur and counts[best] > counts[cur] and \
                    sizes[best] < cap and sizes[cur] > 1:
                part[node] = best
                sizes[cur] -= 1
                sizes[best] += 1
                moved += 1
        if moved == 0:
            break
    return part


def edge_cut(edges: Array, part: Array) -> int:
    return int(np.sum(part[edges[:, 0]] != part[edges[:, 1]]))


# ---------------------------------------------------------------------------
# size-aware (ragged) padding: bucket scheme
# ---------------------------------------------------------------------------

def pad_ladder(limit: int) -> list[int]:
    """The power-of-two-ish pad bucket boundaries up to ``limit``.

    All values are multiples of 8 (TPU sublane) and the ladder is geometric
    — {8, 16, 24, 32, 48, 64, 96, 128, 192, 256, ...}: ratio 2 on the
    single smallest step (8→16, the sublane floor) and ≤ 1.5 from 16 up —
    so bucketed padding wastes at most ~44% of a tiny community's rows
    (size 9 → bucket 16) and ~33% beyond the first step, where the
    global-max pad wastes up to ``n_pad / size``.
    """
    vals = {8}
    k = 16
    while k <= max(int(limit), 8) * 2:
        vals.add(k)
        vals.add(3 * k // 2)
        k *= 2
    return sorted(vals)


def bucket_pad_sizes(sizes: Array, n_pad: int) -> Array:
    """Per-community padded row counts under the bucket scheme.

    Each community pads to the smallest ladder bucket ≥ its size, capped at
    the layout's physical ``n_pad`` stride (communities in the top bucket
    keep the global pad).  Empty communities pad to zero rows.
    """
    ladder = pad_ladder(n_pad)
    out = np.zeros(len(sizes), dtype=np.int32)
    for i, s in enumerate(np.asarray(sizes)):
        if s <= 0:
            continue
        b = next((v for v in ladder if v >= s), ladder[-1])
        out[i] = min(int(b), int(n_pad))
    return out


def partition_quality(num_nodes: int, edges: Array, part: Array,
                      num_parts: int | None = None) -> dict:
    """Quality metrics a partition method is judged on (host-side, cheap).

    ``edge_cut`` is exactly the inter-community block volume a p2p
    transport wires; ``max_deg`` the ELL fan-in of the block layout it
    induces (community graph row degree incl. the self block — identical to
    ``BlockCSR.max_deg`` since Ã blocks are nonzero iff an edge crosses the
    community pair); ``balance`` the heaviest part over the strict cap
    ``ceil(N / M)`` (≤ 1.0 means the hard contract cap holds).
    """
    part = np.asarray(part)
    used = int(part.max()) + 1
    # honour the requested part count (empty trailing parts still count
    # toward the cap), but never index below what the labels actually use
    m = used if num_parts is None else max(int(num_parts), used)
    sizes = np.bincount(part, minlength=m)
    cap = int(np.ceil(num_nodes / m))
    nbr = np.zeros((m, m), dtype=bool)
    pu, pv = part[edges[:, 0]], part[edges[:, 1]]
    nbr[pu, pv] = True
    nbr[pv, pu] = True
    np.fill_diagonal(nbr, True)
    cut = edge_cut(edges, part)
    # padding the layout will pay for this partition's size skew: the global
    # scheme pads every community to max(sizes) (8-aligned), the bucket
    # scheme to its own power-of-two-ish bucket (bucket_pad_sizes)
    n_pad = -(-int(sizes.max()) // 8) * 8
    bucketed = bucket_pad_sizes(sizes, n_pad)
    return {
        "num_parts": m,
        "edge_cut": cut,
        "cut_frac": cut / max(int(edges.shape[0]), 1),
        "balance": float(sizes.max()) / cap,
        "min_size": int(sizes.min()),
        "max_size": int(sizes.max()),
        "max_deg": int(nbr.sum(axis=1).max()),
        "nnz_blocks": int(nbr.sum()),
        "n_pad": n_pad,
        "pad_rows_global": int(m * n_pad - sizes.sum()),
        "pad_rows_bucketed": int(bucketed.sum() - sizes.sum()),
    }


def shard_neighbor_graph(neighbor_mask: Array, n_shards: int
                         ) -> tuple[list[Array], Array]:
    """Lift the community topology to the mesh-shard level.

    With communities laid out community-major (``BlockCSR.shard_slice``),
    shard ``s`` hosts lanes ``[s·k, (s+1)·k)`` and its subproblems read the
    payload rows ``r ∈ ∪_{m∈lanes(s)} N_m ∪ {m}`` — the per-shard union of
    the ELL neighbour indices.  Returns:

      * ``needed``: per shard, the sorted global community ids it must hold
        (its own lanes always included — they are resident, not wired);
      * ``shard_adj``: (n_shards, n_shards) bool, ``[dst, src]`` True when
        ``dst`` needs at least one community hosted on ``src`` (diagonal
        excluded) — the shard-to-shard edge set a point-to-point transport
        schedules over.
    """
    nbr = np.asarray(neighbor_mask, bool)
    m = nbr.shape[0]
    if n_shards <= 0 or m % n_shards:
        raise ValueError(f"M={m} not divisible by n_shards={n_shards}")
    k = m // n_shards
    needed: list[Array] = []
    shard_adj = np.zeros((n_shards, n_shards), dtype=bool)
    for s in range(n_shards):
        rows = nbr[s * k:(s + 1) * k].any(axis=0)
        rows[s * k:(s + 1) * k] = True          # own lanes: resident
        ids = np.flatnonzero(rows).astype(np.int32)
        needed.append(ids)
        src_shards = np.unique(ids // k)
        shard_adj[s, src_shards] = True
        shard_adj[s, s] = False
    return needed, shard_adj


def halo_readers(neighbor_mask: Array) -> list[Array]:
    """Reverse community dependencies: who *reads* each community.

    ``readers[r]`` is the sorted set of communities m with
    ``neighbor_mask[m, r]`` — every m whose aggregation
    Σ_{r'∈N_m} Ã_{m,r'} Z_{r'} consumes community r's rows (m = r itself
    included via the diagonal).  This is exactly the per-community view of
    ``shard_neighbor_graph(neighbor_mask, M)`` transposed, and is what the
    serving engine's incremental invalidation walks: a feature update to a
    node of community r dirties Z_l of ``readers``-closure communities and
    the *halo* entries of ``readers[r] \\ {r}`` (serve.CommunityServer).
    """
    nbr = np.asarray(neighbor_mask, bool)
    return [np.flatnonzero(nbr[:, r]).astype(np.int32)
            for r in range(nbr.shape[0])]


def read_closure(neighbor_mask: Array, seeds: Array, hops: int) -> list[Array]:
    """Per-hop dirty sets of a community update.

    ``out[l]`` (l = 0..hops) is the sorted communities whose layer-l
    activations change when the layer-0 rows of ``seeds`` change:
    ``out[0] = seeds`` and ``out[l] = readers(out[l-1])`` — monotone
    non-shrinking because the diagonal makes every community its own
    reader.  Pure topology (no layout needed); the serving engine keys its
    cache invalidation off these sets and the tests check the dropped
    entries match them exactly.
    """
    nbr = np.asarray(neighbor_mask, bool)
    cur = np.zeros(nbr.shape[0], dtype=bool)
    cur[np.asarray(seeds, dtype=np.int64)] = True
    out = [np.flatnonzero(cur).astype(np.int32)]
    for _ in range(int(hops)):
        cur = nbr[:, cur].any(axis=1)
        out.append(np.flatnonzero(cur).astype(np.int32))
    return out


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """Block-compressed Ã: only the nnz present Ã_{m,r} blocks are stored.

    Memory is O(nnz · n_pad²) instead of the dense layout's O(M² · n_pad²);
    on a power-law community graph nnz grows ~linearly in M while M² does
    not.  Two views of the same blocks:

      * CSR-of-blocks (``indptr``/``indices``/``blocks``) — host-side
        compression, variable fan-in per row;
      * ELL (``ell_indices``/``ell_mask`` into ``ell_blocks``) — every row
        padded to the max fan-in ``max_deg``, fixed-shape and therefore the
        jit/vmap-friendly form the aggregation kernels consume.

    The layout is ragged-aware: ``sizes``/``row_counts`` carry the true and
    padded-per-bucket rows of every community (CommunityLayout), so block
    (m, r) holds real data only in its leading ``(sizes[m], sizes[r])``
    corner — the ELL kernel guards the pad rows out of the DMA+accumulate
    via the scalar-prefetched counts (``ell_row_counts``).
    """

    num_parts: int
    n_pad: int
    indptr: Array       # (M+1,) int32 — row m's blocks are [indptr[m], indptr[m+1])
    indices: Array      # (nnz,) int32 — source community of each stored block
    blocks: Array       # (nnz, n_pad, n_pad) float32
    ell_indices: Array  # (M, max_deg) int32 (rows padded with index 0)
    ell_mask: Array     # (M, max_deg) float32 (1 = real block, 0 = pad)
    ell_blocks: Array   # (M, max_deg, n_pad, n_pad) float32
    sizes: "Array | None" = None       # (M,) true rows per community
    row_counts: "Array | None" = None  # (M,) padded rows (bucket scheme)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def max_deg(self) -> int:
        return int(self.ell_indices.shape[1])

    @property
    def ell_nbytes(self) -> int:
        """Device-resident bytes of the ELL view (blocks + indices + mask)."""
        return (self.ell_blocks.nbytes + self.ell_indices.nbytes
                + self.ell_mask.nbytes)

    def shard_slice(self, shard: int, n_shards: int
                    ) -> tuple[Array, Array, Array]:
        """ELL rows for the communities hosted on mesh shard ``shard``.

        Community m's ELL row sits at index m (community-major order — the
        same order ``CommunityLayout.pack`` uses for Z), so sharding the
        leading axis with ``P('comm')`` places rows [s·k, (s+1)·k) on shard
        s; this helper extracts that exact slice host-side (benchmarks,
        per-shard byte accounting).  ``ell_indices`` stay *global* community
        ids — they index the gathered (M, n_pad, C) payload, not local rows.
        """
        if self.num_parts % n_shards:
            raise ValueError(f"M={self.num_parts} not divisible by "
                             f"n_shards={n_shards}")
        k = self.num_parts // n_shards
        sl = slice(shard * k, (shard + 1) * k)
        return self.ell_blocks[sl], self.ell_indices[sl], self.ell_mask[sl]

    def ell_row_counts(self) -> tuple[Array, Array]:
        """Per-lane and per-neighbour padded row counts for the ELL kernel.

        Returns ``(row_counts, nbr_counts)``: (M,) rows each output lane
        owns and (M, max_deg) rows each stored neighbour block contributes
        (0 on padding slots).  With no ragged metadata both default to the
        full ``n_pad`` — the global-pad behaviour.
        """
        m = self.num_parts
        if self.row_counts is None:
            rows = np.full(m, self.n_pad, dtype=np.int32)
        else:
            rows = np.asarray(self.row_counts, dtype=np.int32)
        nbr = rows[self.ell_indices] * (np.asarray(self.ell_mask) > 0)
        return rows, nbr.astype(np.int32)

    def to_dense(self) -> Array:
        """Reconstruct the dense (M, M, n_pad, n_pad) block tensor.

        Ragged layouts reconstruct identically: pad rows/cols of every
        stored block are zero by construction (asserted in tests), so the
        dense tensor is the same whether counts are tracked or not.
        """
        m, n = self.num_parts, self.n_pad
        out = np.zeros((m, m, n, n), dtype=np.float32)
        for row in range(m):
            lo, hi = int(self.indptr[row]), int(self.indptr[row + 1])
            for k in range(lo, hi):
                out[row, int(self.indices[k])] = self.blocks[k]
        return out

    def spmm(self, z_all: Array) -> Array:
        """Σ_{r∈N_m} Ã_{m,r} Z_r via the ELL view — O(nnz·n_pad²·C) FLOPs.

        z_all: (M, n_pad, C) -> (M, n_pad, C).  Host-side (numpy) twin of
        kernels.ops.community_spmm_ell — keep the two contractions in sync:
        like the kernel, pad rows beyond ``row_counts`` are masked out of
        the contraction (a numerical no-op — they are zero — that keeps
        this oracle's semantics identical to the guarded kernel).
        """
        rows, nbr_rows = self.ell_row_counts()
        lane = np.arange(self.n_pad)
        z_g = z_all[self.ell_indices]                # (M, max_deg, n_pad, C)
        z_g = z_g * self.ell_mask[..., None, None]
        z_g = z_g * (lane[None, None, :, None] < nbr_rows[..., None, None])
        out = np.einsum("mdip,mdpc->mic", self.ell_blocks, z_g)
        return out * (lane[None, :, None] < rows[:, None, None])


def compress_blocks(a_blocks: Array, neighbor_mask: Array,
                    sizes: Array | None = None,
                    row_counts: Array | None = None) -> BlockCSR:
    """Build the CSR-of-blocks + ELL views from a dense block tensor."""
    m, _, n_pad, _ = a_blocks.shape
    nbr = np.asarray(neighbor_mask, bool)
    indptr = np.zeros(m + 1, dtype=np.int32)
    indices, blocks = [], []
    for row in range(m):
        cols = np.flatnonzero(nbr[row])
        indptr[row + 1] = indptr[row] + len(cols)
        indices.extend(int(c) for c in cols)
        blocks.extend(a_blocks[row, c] for c in cols)
    indices = np.asarray(indices, dtype=np.int32)
    blocks = np.stack(blocks).astype(np.float32) if blocks else \
        np.zeros((0, n_pad, n_pad), np.float32)

    deg = np.diff(indptr)
    max_deg = int(deg.max()) if m else 0
    ell_indices = np.zeros((m, max_deg), dtype=np.int32)
    ell_mask = np.zeros((m, max_deg), dtype=np.float32)
    ell_blocks = np.zeros((m, max_deg, n_pad, n_pad), dtype=np.float32)
    for row in range(m):
        lo, hi = int(indptr[row]), int(indptr[row + 1])
        d = hi - lo
        ell_indices[row, :d] = indices[lo:hi]
        ell_mask[row, :d] = 1.0
        ell_blocks[row, :d] = blocks[lo:hi]
    return BlockCSR(num_parts=m, n_pad=n_pad, indptr=indptr, indices=indices,
                    blocks=blocks, ell_indices=ell_indices, ell_mask=ell_mask,
                    ell_blocks=ell_blocks, sizes=sizes, row_counts=row_counts)


@dataclasses.dataclass(frozen=True)
class PackedDeviceLayout:
    """Packed Σ-bucket-rows device layout for an ``n_shards`` mesh.

    The strided device layout keeps community m at rows
    ``[m·n_pad, (m+1)·n_pad)`` of an (M, n_pad, C) stack, so the single
    largest community prices every resident Z/U/z0/label tensor.  The
    packed layout instead gives shard s one flat ``(plane_rows, C)``
    plane in which its k lanes sit back to back at their *bucket* row
    counts: community m starts at ``local_offsets[m]`` and owns
    ``row_counts[m]`` rows.  ``plane_rows`` is the max over shards of
    Σ-bucket-rows (shard_map needs one static per-shard shape), so
    resident bytes track true community size instead of ``M·n_pad``.

    The index tables make the packed ↔ blocked conversion a single
    static ``jnp.take(..., mode="fill", fill_value=0)`` each way —
    out-of-range entries encode "pad row / unused plane row", and since
    every trainer tensor is exactly zero beyond ``row_counts`` (the
    zero-outside-counts contract), the round trip is lossless and the
    blocked view is bitwise-identical to the strided layout's shard.
    """

    n_shards: int
    lanes_per_shard: int
    n_pad: int
    plane_rows: int        # S: per-shard packed plane height (8-aligned)
    row_counts: Array      # (M,) effective bucket rows per community
    local_offsets: Array   # (M,) row offset of community m in its plane
    shard_rows: Array      # (n_shards,) true packed rows per shard
    unpack_rows: Array     # (n_shards, k·n_pad) plane row | S (pad -> fill)
    pack_rows: Array       # (n_shards, S) blocked flat row | k·n_pad (fill)

    @property
    def num_parts(self) -> int:
        return int(self.row_counts.shape[0])

    @property
    def total_rows(self) -> int:
        """Rows of the full packed state stack (n_shards · plane_rows)."""
        return self.n_shards * self.plane_rows

    @property
    def true_rows(self) -> int:
        """Σ bucket rows — the ideal (non-shard-max) packed height."""
        return int(self.row_counts.sum())

    def state_rows(self, strided: bool = False) -> int:
        """Leading-dim rows a state tensor holds under either layout."""
        if strided:
            return self.num_parts * self.n_pad
        return self.total_rows

    def global_unpack_rows(self) -> Array:
        """(M·n_pad,) indices into the (total_rows,) packed stack; pad
        rows map out of range (use ``mode='fill'``).

        Memoized: the table is static per layout and both the trainer
        metrics and the serving hot path look it up every call, so the
        Python build loop runs once.  Treat the returned array as
        read-only (every consumer does)."""
        cached = self.__dict__.get("_global_unpack_rows")
        if cached is not None:
            return cached
        m, n, k = self.num_parts, self.n_pad, self.lanes_per_shard
        out = np.full(m * n, self.total_rows, dtype=np.int32)
        for c in range(m):
            s, rc = c // k, int(self.row_counts[c])
            base = s * self.plane_rows + int(self.local_offsets[c])
            out[c * n: c * n + rc] = base + np.arange(rc)
        object.__setattr__(self, "_global_unpack_rows", out)
        return out

    def global_pack_rows(self) -> Array:
        """(total_rows,) indices into the (M·n_pad,) blocked stack;
        unused plane rows map out of range (use ``mode='fill'``).
        Memoized like ``global_unpack_rows`` — read-only result."""
        cached = self.__dict__.get("_global_pack_rows")
        if cached is not None:
            return cached
        m, n, k = self.num_parts, self.n_pad, self.lanes_per_shard
        out = np.full(self.total_rows, m * n, dtype=np.int32)
        for c in range(m):
            s, rc = c // k, int(self.row_counts[c])
            base = s * self.plane_rows + int(self.local_offsets[c])
            out[base: base + rc] = c * n + np.arange(rc)
        object.__setattr__(self, "_global_pack_rows", out)
        return out

    def pack_state(self, x: Array, fill: float = 0.0) -> Array:
        """Host-side (M, n_pad, ...) blocked -> (total_rows, ...) packed."""
        flat = np.asarray(x).reshape((self.num_parts * self.n_pad,)
                                     + x.shape[2:])
        idx = self.global_pack_rows()
        out = np.full((self.total_rows,) + flat.shape[1:], fill, flat.dtype)
        ok = idx < flat.shape[0]
        out[ok] = flat[idx[ok]]
        return out

    def unpack_state(self, x: Array, fill: float = 0.0) -> Array:
        """Host-side (total_rows, ...) packed -> (M, n_pad, ...) blocked."""
        x = np.asarray(x)
        idx = self.global_unpack_rows()
        out = np.full((self.num_parts * self.n_pad,) + x.shape[1:], fill,
                      x.dtype)
        ok = idx < x.shape[0]
        out[ok] = x[idx[ok]]
        return out.reshape((self.num_parts, self.n_pad) + x.shape[1:])


@dataclasses.dataclass(frozen=True)
class CommunityLayout:
    """Community-blocked layout of a graph (paper §2, Fig. 1).

    Nodes are permuted so community m occupies rows [m*n_pad, m*n_pad+n_m);
    the *physical* stride between communities is ``n_pad`` (the global max,
    8-aligned) so every packed tensor keeps a fixed (M, n_pad, ...) shape.
    ``a_blocks[m, r]`` is the dense Ã_{m,r} block; ``neighbor_mask[m, r]``
    marks r ∈ N_m ∪ {m} (nonzero blocks) — the paper's first-order
    communication topology.  When built with ``compressed=True``,
    ``block_csr`` additionally stores only the present blocks
    (CSR-of-blocks / ELL; O(nnz·n_pad²) memory).

    Ragged (size-aware) padding: ``row_counts[m]`` is the number of rows
    community m is *logically* padded to.  Under ``pad_mode="global"`` it is
    ``n_pad`` everywhere (the historic behaviour); under
    ``pad_mode="bucketed"`` each community pads only to its power-of-two-ish
    size bucket (``bucket_pad_sizes``), so pad FLOPs (ELL kernel row-count
    guards), pad wire bytes (row-exact NeighborExchange payloads) and the
    ragged ``blockify`` representation all track true community size instead
    of the single largest community.  Rows in [sizes[m], n_pad) are zero in
    every packed tensor either way — bucketing changes what is *processed*
    and *wired*, never the math.
    """

    num_parts: int
    n_pad: int
    perm: Array            # (N,) original index of packed slot (padded: -1)
    a_blocks: Array        # (M, M, n_pad, n_pad) float32
    node_mask: Array       # (M, n_pad) bool  (True = real node)
    neighbor_mask: Array   # (M, M) bool
    sizes: Array           # (M,) int
    block_csr: "BlockCSR | None" = None
    row_counts: "Array | None" = None   # (M,) int32 — logical pad per community
    pad_mode: str = "global"

    @property
    def nnz_blocks(self) -> int:
        return int(np.asarray(self.neighbor_mask).sum())

    @property
    def pad_rows(self) -> int:
        """Logical padding rows this layout carries (Σ row_counts − Σ sizes)."""
        return int(np.sum(self.eff_row_counts()) - np.sum(self.sizes))

    def eff_row_counts(self) -> Array:
        """(M,) effective per-community padded row counts (global fallback)."""
        if self.row_counts is None:
            return np.full(self.num_parts, self.n_pad, dtype=np.int32)
        return np.asarray(self.row_counts, dtype=np.int32)

    def row_offsets(self) -> Array:
        """(M+1,) ragged row offsets of the ``blockify`` representation."""
        return np.concatenate(
            [[0], np.cumsum(self.eff_row_counts())]).astype(np.int64)

    def compress(self) -> BlockCSR:
        """CSR-of-blocks view of ``a_blocks`` (cached when built with
        ``compressed=True``)."""
        if self.block_csr is not None:
            return self.block_csr
        return compress_blocks(self.a_blocks, self.neighbor_mask,
                               sizes=self.sizes, row_counts=self.row_counts)

    def blockify(self, x: Array, fill: float = 0.0) -> Array:
        """(N, ...) node array -> ragged (R, ...) community-blocked array.

        The ragged twin of ``pack``: community m occupies rows
        [row_offsets()[m], row_offsets()[m] + row_counts[m]) with its
        ``sizes[m]`` real rows first, padded to its *bucket* (not the global
        ``n_pad``), so R = Σ_m row_counts[m] ≤ M·n_pad — the resident-bytes
        win of size-aware padding, exact for any size distribution.
        """
        counts = self.eff_row_counts()
        offs = self.row_offsets()
        out = np.full((int(offs[-1]),) + x.shape[1:], fill, dtype=x.dtype)
        for m in range(self.num_parts):
            members = self.perm[m * self.n_pad:
                                m * self.n_pad + int(self.sizes[m])]
            assert int(self.sizes[m]) <= int(counts[m]), \
                f"community {m}: {self.sizes[m]} rows exceed its " \
                f"{counts[m]}-row bucket"
            out[offs[m]: offs[m] + int(self.sizes[m])] = x[members]
        return out

    def unblockify(self, x: Array) -> Array:
        """Ragged (R, ...) -> (N, ...) in original node order (inverse of
        ``blockify`` on the real rows; pad rows are discarded)."""
        offs = self.row_offsets()
        n = int((self.perm >= 0).sum())
        out = np.zeros((n,) + x.shape[1:], dtype=x.dtype)
        for m in range(self.num_parts):
            members = self.perm[m * self.n_pad:
                                m * self.n_pad + int(self.sizes[m])]
            out[members] = x[offs[m]: offs[m] + int(self.sizes[m])]
        return out

    def device_layout(self, n_shards: int) -> PackedDeviceLayout:
        """Packed Σ-bucket-rows device layout for an ``n_shards`` mesh.

        Shard s (lanes [s·k, (s+1)·k)) packs its communities back to back
        at their bucket row counts; the per-shard plane height is the max
        over shards (fixed shard_map shapes).  Under ``pad_mode="global"``
        every bucket is ``n_pad`` so packed degenerates to strided — the
        memory win needs bucketed counts and k > 1.
        """
        m, n = self.num_parts, self.n_pad
        if n_shards <= 0 or m % n_shards:
            raise ValueError(f"M={m} not divisible by n_shards={n_shards}")
        k = m // n_shards
        rc = self.eff_row_counts().astype(np.int32)
        shard_rows = rc.reshape(n_shards, k).sum(axis=1).astype(np.int32)
        plane = max(int(shard_rows.max()), 8)
        local = np.zeros(m, dtype=np.int32)
        for s in range(n_shards):
            local[s * k:(s + 1) * k] = np.concatenate(
                [[0], np.cumsum(rc[s * k:(s + 1) * k])[:-1]])
        unpack = np.full((n_shards, k * n), plane, dtype=np.int32)
        packr = np.full((n_shards, plane), k * n, dtype=np.int32)
        for c in range(m):
            s, i, cnt = c // k, c % k, int(rc[c])
            rows = np.arange(cnt)
            unpack[s, i * n: i * n + cnt] = int(local[c]) + rows
            packr[s, int(local[c]): int(local[c]) + cnt] = i * n + rows
        return PackedDeviceLayout(
            n_shards=n_shards, lanes_per_shard=k, n_pad=n,
            plane_rows=plane, row_counts=rc, local_offsets=local,
            shard_rows=shard_rows, unpack_rows=unpack, pack_rows=packr)

    def pack(self, x: Array, fill: float = 0.0) -> Array:
        """(N, ...) node array -> (M, n_pad, ...) community-blocked array."""
        out_shape = (self.num_parts * self.n_pad,) + x.shape[1:]
        out = np.full(out_shape, fill, dtype=x.dtype)
        valid = self.perm >= 0
        out[valid.nonzero()[0]] = x[self.perm[valid]]
        return out.reshape((self.num_parts, self.n_pad) + x.shape[1:])

    def unpack(self, x: Array) -> Array:
        """(M, n_pad, ...) -> (N, ...) in original node order."""
        flat = x.reshape((self.num_parts * self.n_pad,) + x.shape[2:])
        n = int((self.perm >= 0).sum())
        out = np.zeros((n,) + x.shape[2:], dtype=x.dtype)
        valid = self.perm >= 0
        out[self.perm[valid]] = flat[valid.nonzero()[0]]
        return out


def build_community_layout(num_nodes: int, edges: Array, part: Array,
                           pad_to: int | None = None,
                           compressed: bool = False,
                           pad_mode: str = "global",
                           num_parts: int | None = None) -> CommunityLayout:
    """``pad_mode``: "global" pads every community to the max size (the
    historic layout); "bucketed" additionally records per-community
    ``row_counts`` under the power-of-two-ish bucket scheme
    (``bucket_pad_sizes``) that the ragged consumers (ELL kernel guards,
    row-exact exchange, ``blockify``) key off.  ``num_parts`` forces the
    community count (trailing empty communities are otherwise dropped)."""
    if pad_mode not in ("global", "bucketed"):
        raise ValueError(f"unknown pad_mode {pad_mode!r}; "
                         f"expected 'global' or 'bucketed'")
    used = int(part.max()) + 1 if len(part) else 1
    if num_parts is None:
        num_parts = used
    elif int(num_parts) < used:
        raise ValueError(f"num_parts={num_parts} below the {used} "
                         f"communities present in part — pass a partition "
                         f"that fits or raise num_parts")
    else:
        num_parts = int(num_parts)
    sizes = np.bincount(part, minlength=num_parts)
    n_pad = int(sizes.max()) if pad_to is None else int(pad_to)
    # round pad up to a multiple of 8 (TPU sublane) for kernel friendliness
    n_pad = -(-n_pad // 8) * 8
    row_counts = bucket_pad_sizes(sizes, n_pad) if pad_mode == "bucketed" \
        else None

    a_tilde = normalized_adjacency(num_nodes, edges)
    perm = np.full(num_parts * n_pad, -1, dtype=np.int64)
    slot_of = np.zeros(num_nodes, dtype=np.int64)
    for m in range(num_parts):
        members = np.flatnonzero(part == m)
        perm[m * n_pad: m * n_pad + len(members)] = members
        slot_of[members] = m * n_pad + np.arange(len(members))

    big = np.zeros((num_parts * n_pad, num_parts * n_pad), dtype=np.float32)
    valid = np.flatnonzero(perm >= 0)
    big[np.ix_(valid, valid)] = a_tilde[np.ix_(perm[valid], perm[valid])]
    a_blocks = (big.reshape(num_parts, n_pad, num_parts, n_pad)
                   .transpose(0, 2, 1, 3).copy())

    node_mask = (perm >= 0).reshape(num_parts, n_pad)
    neighbor_mask = (np.abs(a_blocks).sum(axis=(2, 3)) > 0)
    np.fill_diagonal(neighbor_mask, True)
    a_blocks = a_blocks.astype(np.float32)
    csr = compress_blocks(a_blocks, neighbor_mask, sizes=sizes,
                          row_counts=row_counts) if compressed else None
    return CommunityLayout(num_parts=num_parts, n_pad=n_pad, perm=perm,
                           a_blocks=a_blocks,
                           node_mask=node_mask, neighbor_mask=neighbor_mask,
                           sizes=sizes, block_csr=csr,
                           row_counts=row_counts, pad_mode=pad_mode)


# ---------------------------------------------------------------------------
# Synthetic benchmark graphs (Amazon Computers / Photo statistics, Table 2).
# The real datasets are unavailable offline; we match N / features / classes /
# train-test counts with a stochastic block model whose blocks align with the
# label classes, so community structure (the paper's premise) is present.
# ---------------------------------------------------------------------------

def synthetic_powerlaw_communities(num_parts: int, nodes_per_part: int = 32,
                                   attach: int = 2, p_in: float = 0.3,
                                   inter_edges: int = 4, seed: int = 0,
                                   num_classes: int = 4, feat_dim: int = 16,
                                   size_skew: float = 0.0
                                   ) -> tuple[Graph, Array]:
    """Graph of M dense communities whose *inter-community* topology is a
    preferential-attachment (Barabási–Albert) graph: block fan-in follows a
    power law, so nnz Ã blocks grows ~O(M·attach) while the dense layout is
    O(M²) — the regime where block compression and neighbour-only
    communication pay off.  Returns (graph, ground-truth partition).

    ``size_skew > 0`` makes the *community sizes themselves* power-law
    distributed (size ∝ rank^-skew, total held at M·nodes_per_part, min
    size 1), with the LARGE communities at the high (late, BA-peripheral)
    indices and the early hubs small — a dense small core relaying between
    big leaf communities.  Keeping size anti-correlated with block degree
    makes the benchmark isolate *padding* waste: the irreducible (true-row)
    wire volume stays comparable to the uniform graph's, so any global-pad
    overhead measured against it is pure pad bytes.  This is the regime
    where a single global ``n_pad`` wastes pad FLOPs/bytes proportional to
    the skew and size-aware (bucketed) padding pays (BENCH_speedup.json
    ``m32_ragged``).  ``size_skew=0`` reproduces the historic equal-size
    graphs bit-for-bit (same rng stream).
    """
    rng = np.random.default_rng(seed)
    m, n_c = num_parts, nodes_per_part
    if size_skew > 0:
        w = (np.arange(m) + 1.0) ** (-float(size_skew))
        w = w[::-1]                              # big sizes on the leaves
        sizes = np.maximum(1, np.floor(w / w.sum() * (m * n_c)).astype(int))
        # restore N == M·nodes_per_part: the min-size-1 bumps can overshoot
        # the floor() undershoot at extreme skew, so walk the correction
        # from the largest community down, never dropping any below 1
        delta = m * n_c - int(sizes.sum())
        i = m - 1
        while delta < 0 and i >= 0:
            take = min(int(sizes[i]) - 1, -delta)
            sizes[i] -= take
            delta += take
            i -= 1
        sizes[-1] += delta
    else:
        sizes = np.full(m, n_c, dtype=int)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(int)
    n = int(offsets[-1])
    part = np.repeat(np.arange(m, dtype=np.int32), sizes)

    edges: list[tuple[int, int]] = []
    # dense intra-community structure (ER with p_in, plus a ring so every
    # community is connected)
    for c in range(m):
        base, n_cc = int(offsets[c]), int(sizes[c])
        for i in range(n_cc):
            edges.append((base + i, base + (i + 1) % n_cc))
        pairs = np.argwhere(
            np.triu(rng.random((n_cc, n_cc)) < p_in, k=2))
        edges.extend((base + int(i), base + int(j)) for i, j in pairs)

    # preferential attachment over communities
    deg = np.ones(m)
    com_edges: set[tuple[int, int]] = set()
    for c in range(1, m):
        k = min(attach, c)
        probs = deg[:c] / deg[:c].sum()
        targets = rng.choice(c, size=k, replace=False, p=probs)
        for t in targets:
            com_edges.add((min(c, int(t)), max(c, int(t))))
            deg[c] += 1
            deg[t] += 1
    # each community edge becomes a few node-level bridge edges
    for c1, c2 in sorted(com_edges):
        for _ in range(inter_edges):
            u = int(offsets[c1]) + int(rng.integers(sizes[c1]))
            v = int(offsets[c2]) + int(rng.integers(sizes[c2]))
            edges.append((u, v))

    e = np.unique(np.sort(np.asarray(edges, dtype=np.int32), axis=1), axis=0)
    e = e[e[:, 0] != e[:, 1]]

    labels = (part % num_classes).astype(np.int32)
    centers = rng.normal(0.0, 1.0, size=(num_classes, feat_dim))
    feats = (centers[labels]
             + rng.normal(0, 0.8, size=(n, feat_dim))).astype(np.float32)
    order = rng.permutation(n)
    train_mask = np.zeros(n, dtype=bool)
    test_mask = np.zeros(n, dtype=bool)
    train_mask[order[: n // 3]] = True
    test_mask[order[n // 3: 2 * n // 3]] = True
    return Graph(edges=e, features=feats, labels=labels,
                 train_mask=train_mask, test_mask=test_mask,
                 num_classes=num_classes), part


DATASET_STATS = {
    # name: (nodes, train, test, classes, features, avg_degree)
    "amazon_computers": (13752, 1000, 1000, 10, 767, 35.8),
    "amazon_photo": (7650, 800, 1000, 8, 745, 31.1),
    "amazon_computers_mini": (2752, 600, 600, 10, 767, 18.0),
    "amazon_photo_mini": (1530, 400, 400, 8, 745, 16.0),
}


def synthetic_sbm(name: str = "amazon_computers_mini", seed: int = 0,
                  p_in_out_ratio: float = 12.0) -> Graph:
    if name not in DATASET_STATS:
        raise KeyError(f"unknown dataset {name!r}; have {list(DATASET_STATS)}")
    n, n_train, n_test, k, c0, deg = DATASET_STATS[name]
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, size=n).astype(np.int32)

    # SBM edge sampling: expected degree ``deg``, within-class edges
    # p_in_out_ratio times likelier than cross-class.
    p_out = deg / (n * (p_in_out_ratio / k + (1 - 1 / k)))
    p_in = p_in_out_ratio * p_out
    same = labels[:, None] == labels[None, :]
    prob = np.where(same, p_in, p_out)
    upper = np.triu(rng.random((n, n)) < prob, k=1)
    edges = np.argwhere(upper).astype(np.int32)

    # class-informative Gaussian features
    centers = rng.normal(0.0, 1.0, size=(k, c0)).astype(np.float32)
    feats = centers[labels] + rng.normal(0, 1.2, size=(n, c0)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-8

    order = rng.permutation(n)
    train_mask = np.zeros(n, dtype=bool)
    test_mask = np.zeros(n, dtype=bool)
    train_mask[order[:n_train]] = True
    test_mask[order[n_train:n_train + n_test]] = True
    return Graph(edges=edges, features=feats, labels=labels,
                 train_mask=train_mask, test_mask=test_mask, num_classes=k)
