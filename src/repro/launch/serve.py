"""Serving launcher: cached community-block GCN inference.

Trains a small community-partitioned GCN (the same power-law benchmark
family as benchmarks/serving.py), stands up a ``repro.serve
.CommunityServer`` over the trained weights, and drives a Zipf request
stream through the batched serving path, printing steady-state latency
percentiles, QPS and cache hit rate.  ``--update`` then applies a
feature update mid-stream to show incremental invalidation: only the
read closure of the touched communities recomputes.

    PYTHONPATH=src python -m repro.launch.serve --parts 16 --epochs 3
    PYTHONPATH=src python -m repro.launch.serve --no-cache   # baseline
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _percentile_ms(times: list, q: float) -> float:
    return float(np.percentile(np.asarray(times) * 1e3, q))


def _drive(server, stream: np.ndarray, batch: int) -> dict:
    n_batches = len(stream) // batch
    warmup = max(n_batches // 4, 1)
    times = []
    h0 = t0 = 0
    for i in range(n_batches):
        if i == warmup:
            h0, t0 = server.request_hits, server.request_total
        tic = time.perf_counter()
        server.serve(stream[i * batch:(i + 1) * batch])
        if i >= warmup:
            times.append(time.perf_counter() - tic)
    hits = server.request_hits - h0
    total = server.request_total - t0
    return {"p50_ms": _percentile_ms(times, 50),
            "p99_ms": _percentile_ms(times, 99),
            "qps": len(times) * batch / max(sum(times), 1e-9),
            "hit_rate": hits / max(total, 1)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="cached community-block GCN serving demo")
    ap.add_argument("--parts", type=int, default=16, help="communities M")
    ap.add_argument("--nodes-per-part", type=int, default=24)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--zipf-s", type=float, default=1.1)
    ap.add_argument("--embed-capacity", type=int, default=None,
                    help="embedding-cache blocks (default: 1.25*M)")
    ap.add_argument("--halo-capacity", type=int, default=64)
    ap.add_argument("--admission", choices=("zipf", "lru"), default="zipf")
    ap.add_argument("--no-cache", action="store_true",
                    help="capacity-0 caches: every batch recomputes")
    ap.add_argument("--fused", action="store_true",
                    help="cold path through the fused agg→GEMM kernel")
    ap.add_argument("--update", type=int, default=0, metavar="K",
                    help="after the stream, update K node features and "
                         "report the invalidation footprint")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core import gcn, graph
    from repro.core.parallel import ParallelADMMTrainer, TrainerConfig
    from repro.core.subproblems import ADMMConfig
    from repro.serve import CommunityServer, ServeConfig, zipf_node_stream

    g, part = graph.synthetic_powerlaw_communities(
        args.parts, nodes_per_part=args.nodes_per_part, attach=2,
        seed=args.seed, feat_dim=16, size_skew=1.0)
    cfg = gcn.GCNConfig(layer_dims=(16, 32, g.num_classes))
    tr = ParallelADMMTrainer(
        cfg, ADMMConfig(nu=1e-3, rho=1e-3), g, num_parts=args.parts,
        seed=args.seed, part=part,
        config=TrainerConfig(transport="p2p", compressed=True,
                             pad_mode="bucketed", packed=True))
    print(f"[serve] training M={args.parts} model on N={g.num_nodes} "
          f"({args.epochs} epochs)...")
    tr.train(args.epochs)
    _, test_acc, _ = tr._metrics(tr.state)
    print(f"[serve] test_acc={float(test_acc):.4f}")

    ecap = args.embed_capacity
    if ecap is None:
        ecap = max(args.parts + args.parts // 4, 8)
    scfg = ServeConfig(embed_capacity=ecap,
                       halo_capacity=args.halo_capacity,
                       cache_enabled=not args.no_cache,
                       admission=args.admission, fused=args.fused,
                       max_batch=args.batch)
    server = CommunityServer.from_trainer(tr, scfg)

    stream = zipf_node_stream(g.num_nodes, args.requests, s=args.zipf_s,
                              seed=args.seed + 1)
    res = _drive(server, stream, args.batch)
    mode = "cold (cache disabled)" if args.no_cache else \
        f"cached (embed={ecap}, halo={args.halo_capacity}, " \
        f"admission={args.admission})"
    print(f"[serve] {mode}")
    print(f"[serve] Zipf(s={args.zipf_s}) x {args.requests} requests, "
          f"batch {args.batch}:")
    print(f"[serve]   p50 {res['p50_ms']:.3f} ms   p99 "
          f"{res['p99_ms']:.3f} ms   {res['qps']:.0f} qps   "
          f"hit rate {res['hit_rate']:.3f}")

    if args.update > 0:
        rng = np.random.default_rng(args.seed + 2)
        ids = rng.choice(g.num_nodes, size=args.update, replace=False)
        feats = np.asarray(g.features)[ids] + rng.normal(
            scale=0.1, size=(args.update, cfg.layer_dims[0])).astype(
            np.float32)
        rep = server.update_features(ids, feats)
        dirty = [len(c) for c in rep["dirty"]]
        print(f"[serve] updated {args.update} node feature row(s): "
              f"dirty communities per hop {dirty} of M={args.parts}; "
              f"dropped {len(rep['embed'])} embed / {len(rep['halo'])} "
              f"halo cache entries")
        res2 = _drive(server, stream, args.batch)
        print(f"[serve]   post-update p50 {res2['p50_ms']:.3f} ms   "
              f"hit rate {res2['hit_rate']:.3f} (recovered from cache)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
