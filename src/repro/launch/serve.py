"""Serving launcher: batched prefill + greedy decode.

``python -m repro.launch.serve --arch gemma-2b --reduced --batch 4
--prompt-len 32 --gen 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.build import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = make_model(cfg)
    params = model.init(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.gen

    decode = jax.jit(lambda p, c, t: model.decode_step(p, c, t))

    # prefill by replaying the prompt through the decode path (cache fill)
    caches = model.init_cache(args.batch, max_len)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = decode(params, caches, jnp.asarray(prompts[:, t:t + 1]))
    t_prefill = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        generated.append(np.asarray(tok)[:, 0])
        logits, caches = decode(params, caches, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_gen = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill {t_prefill*1e3:.1f} ms, "
          f"decode {t_gen/args.gen*1e3:.2f} ms/token")
    for i in range(min(args.batch, 2)):
        print(f"[serve] stream {i}: ...{prompts[i, -5:].tolist()} => "
              f"{gen[i].tolist()}")


if __name__ == "__main__":
    main()
