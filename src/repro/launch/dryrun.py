import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init), which is why the docstring sits below them.

DOC = """Multi-pod dry-run: lower + compile every (arch × input-shape) on the
production meshes — 16×16 (single pod, 256 chips) and 2×16×16 (512 chips).

No real allocation: params/optimizer/caches/batches are ShapeDtypeStructs.
Per combination this records memory_analysis, cost_analysis and the
collective-op byte census parsed from the optimized HLO, feeding
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.shapes import InputShape
from repro.launch import mesh as mesh_lib
from repro.launch import roofline
from repro.models.build import make_model
from repro.sharding import partition

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# long_500k: dense/MoE/VLM/audio archs run their sliding-window variant
LONG_CONTEXT_WINDOW = 4096
SUBQUADRATIC = ("ssm", "hybrid")


def adapt_config(arch: str, shape: InputShape):
    cfg = get_config(arch)
    notes = []
    if shape.name == "long_500k" and cfg.arch_type not in SUBQUADRATIC:
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
        notes.append(f"sliding_window={LONG_CONTEXT_WINDOW} (long_500k "
                     "sub-quadratic variant, DESIGN.md)")
    return cfg, notes


def abstract_tree(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_lowered(arch: str, shape_name: str, mesh, optimized: bool = False):
    shape = INPUT_SHAPES[shape_name]
    cfg, notes = adapt_config(arch, shape)
    model = make_model(cfg)
    rolling = shape.name == "long_500k" and cfg.arch_type not in SUBQUADRATIC
    if optimized:
        notes.append("optimized: sharding hints + deferred grad reduction "
                     "(EXPERIMENTS.md §Perf)")

    params_s = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = partition.param_specs(cfg, mesh, params_s)
    batch_s = model.input_specs(shape)
    bspecs = partition.batch_specs(cfg, mesh, batch_s)

    dp = mesh_lib.data_axes(mesh)

    def logits_pspec():
        bsp = dp if shape.global_batch % _dp_size(mesh) == 0 else None
        vsp = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
        return P(bsp, None, vsp)

    if shape.step == "train":
        opt = model.init_optimizer()
        opt_s = jax.eval_shape(opt.init, params_s)
        ospecs = partition.opt_state_specs(cfg, mesh, params_s, opt_s)
        metric_names = ("ce", "aux", "loss") + (
            ("mtp_ce",) if cfg.mtp_depth else ())
        out_specs = (pspecs, ospecs, {k: P() for k in metric_names})
        if optimized:
            import functools
            step = functools.partial(model.train_step_deferred, mesh)
        else:
            step = model.train_step
        fn = jax.jit(step,
                     in_shardings=(shardings(mesh, pspecs),
                                   shardings(mesh, ospecs),
                                   shardings(mesh, bspecs)),
                     out_shardings=shardings(mesh, out_specs))
        lowered = fn.lower(params_s, opt_s, batch_s)
    elif shape.step == "prefill":
        def prefill_fn(params, batch):
            logits, _, _ = model.forward(params, batch, last_only=True)
            return logits
        fn = jax.jit(prefill_fn,
                     in_shardings=(shardings(mesh, pspecs),
                                   shardings(mesh, bspecs)),
                     out_shardings=NamedSharding(mesh, logits_pspec()))
        lowered = fn.lower(params_s, batch_s)
    else:   # decode
        cache_s = model.cache_specs(shape, rolling=rolling)
        cspecs = partition.cache_specs(cfg, mesh, cache_s)
        tok_spec = jax.tree.map(lambda _: P(), batch_s)

        def decode_fn(params, caches, batch):
            return model.decode_step(params, caches, batch["tokens"],
                                     rolling=rolling)
        fn = jax.jit(decode_fn,
                     in_shardings=(shardings(mesh, pspecs),
                                   shardings(mesh, cspecs),
                                   shardings(mesh, tok_spec)),
                     out_shardings=(NamedSharding(mesh, logits_pspec()),
                                    shardings(mesh, cspecs)))
        lowered = fn.lower(params_s, cache_s, batch_s)
    return cfg, lowered, notes


def _dp_size(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in mesh_lib.data_axes(mesh)]))


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Path = RESULTS_DIR, optimized: bool = False) -> dict:
    from repro.sharding.hints import sharding_hints
    import contextlib
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    hint_ctx = sharding_hints(mesh, moe_a2a=True) if optimized \
        else contextlib.nullcontext()
    with mesh, hint_ctx:
        cfg, lowered, notes = build_lowered(arch, shape_name, mesh,
                                            optimized=optimized)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    census = roofline.hlo_census(hlo)
    coll = {op: census.collectives[op] for op in roofline.COLLECTIVE_OPS}
    coll["total_bytes"] = census.collective_bytes
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": n_chips,
        "step": INPUT_SHAPES[shape_name].step,
        "notes": notes,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")
                 if isinstance(cost, dict)},
        # trip-count-aware HLO census (per-device module) — the roofline
        # source of truth; raw cost_analysis kept above for comparison
        "census": {
            "flops": census.flops,
            "hbm_bytes": census.hbm_bytes,
            "collective_bytes": census.collective_bytes,
            "while_trips": sorted(set(int(t) for t in census.while_trips)),
        },
        "analytic_hbm_bytes": roofline.analytic_hbm_bytes(
            cfg, INPUT_SHAPES[shape_name], INPUT_SHAPES[shape_name].step,
            n_chips),
        "model_flops": roofline.model_flops(
            cfg, INPUT_SHAPES[shape_name], INPUT_SHAPES[shape_name].step),
        "collectives": coll,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "__opt" if optimized else ""
    out = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    out.write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="optimized variant (sharding hints + deferred "
                         "grad reduction) -> *__opt.json")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    failures = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch} × {shape} × " + \
                ("2x16x16" if args.multi_pod else "16x16") + \
                (" [opt]" if args.opt else "")
            try:
                r = run_one(arch, shape, args.multi_pod, Path(args.out),
                            optimized=args.opt)
                peak = r["memory"]["peak_bytes"]
                peak_s = f"{peak/2**30:.2f} GiB/chip" if peak else "n/a"
                print(f"[dryrun] OK   {tag}: compile {r['compile_s']}s, "
                      f"peak {peak_s}, flops {r['cost'].get('flops')}")
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: "
                         + "; ".join(t for t, _ in failures))
    print("[dryrun] all combinations lowered and compiled")


if __name__ == "__main__":
    main()
