"""Production meshes.

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before the first jax call).

Target hardware (roofline constants): TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

from repro.util.compat import make_mesh

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 512 if multi_pod else 256
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever host devices exist (tests/examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return make_mesh((data, model_axis), ("data", "model"),
                     devices=jax.devices()[:data * model_axis])


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
