"""Training launcher: ``python -m repro.launch.train --arch gemma-2b
--reduced --steps 100``.

On this CPU container use ``--reduced`` (the full configs are exercised by
the dry-run); on a real TPU slice drop it and pass ``--production-mesh``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_config
from repro.data import TokenPipeline, synthetic_token_batches
from repro.launch import mesh as mesh_lib
from repro.models.build import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.is_encoder_decoder or cfg.arch_type == "vlm":
        raise SystemExit(
            f"{args.arch}: use examples/ drivers for multimodal batches")
    model = make_model(cfg)

    mesh = mesh_lib.make_production_mesh() if args.production_mesh \
        else mesh_lib.make_host_mesh()
    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    source = synthetic_token_batches(cfg.vocab_size, args.batch, args.seq,
                                     seed=args.seed)
    pipeline = TokenPipeline(source, mesh=mesh)

    with mesh:
        params = model.init(jax.random.key(args.seed))
        opt_state = model.init_optimizer().init(params)
        step_fn = jax.jit(model.train_step)

        losses = []
        t0 = time.perf_counter()
        for step in range(args.steps):
            batch = next(pipeline)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.perf_counter() - t0
                print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                      f"({dt:.1f}s elapsed)")
            if args.ckpt_dir and args.ckpt_every and \
                    step % args.ckpt_every == args.ckpt_every - 1:
                path = ckpt_lib.save(args.ckpt_dir,
                                     {"params": params, "opt": opt_state},
                                     step=step)
                print(f"[train] checkpoint -> {path}")

    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
