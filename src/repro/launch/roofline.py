"""Roofline analysis from the compiled dry-run artifact.

The HLO parsing and the trip-count-aware census this is built on live in
``repro.analysis.hlo`` (they moved there when the static-analysis rule
registry grew around them); the historical names — ``parse_hlo``,
``Census``, ``hlo_census``, ``collective_bytes``, ``COLLECTIVE_OPS`` —
are re-exported here for the dryrun/benchmark callers.

Roofline terms per (arch × shape × mesh), in seconds (per-chip, the HLO is
the per-device partitioned module):

  compute    = flops            / 197 TFLOP/s
  memory     = hbm_bytes        / 819 GB/s
  collective = collective_bytes / 50 GB/s/link
"""
from __future__ import annotations

from typing import Any

from repro.analysis.hlo import (COLLECTIVE_OPS, Census, Computation, Instr,
                                collective_bytes, hlo_census, parse_hlo)
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

__all__ = [
    "COLLECTIVE_OPS", "Census", "Computation", "Instr", "collective_bytes",
    "hlo_census", "parse_hlo", "roofline_terms", "analytic_hbm_bytes",
    "fused_agg_traffic", "model_flops",
]


def roofline_terms(flops: float, hbm_bytes: float, collective_total: float,
                   exposed_collective: "float | None" = None
                   ) -> dict[str, Any]:
    """Per-chip terms in seconds (inputs are per-device census numbers).

    ``exposed_collective`` (bytes) switches the collective term to
    overlap-aware pricing: pass the exposed wire volume of the staged
    exchange schedule (``messages.overlap_stats(...)['exposed_wire_bytes']``
    — what the double-buffered aggregation cannot hide behind compute)
    and the roofline prices only that, with the full scheduled volume
    kept as ``collective_total_s`` for the no-overlap comparison.
    """
    terms = {"compute_s": flops / PEAK_FLOPS,
             "memory_s": hbm_bytes / HBM_BW}
    if exposed_collective is None:
        terms["collective_s"] = collective_total / ICI_BW
    else:
        terms["collective_s"] = exposed_collective / ICI_BW
        terms["collective_total_s"] = collective_total / ICI_BW
        terms["collective_exposed_bytes"] = float(exposed_collective)
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    return terms


def fused_agg_traffic(agg_rows: int, site_dims, itemsize: int = 4
                      ) -> dict[str, Any]:
    """HBM traffic of the aggregation→GEMM intermediates, per shard per
    iteration, for the fused-vs-unfused comparison (BENCH_speedup's
    ``m32_fused`` section).

    ``agg_rows`` is the row count of each aggregated ``(k, n_pad, C)``
    stack (k·n_pad per shard); ``site_dims`` lists one ``(c_in, c_out)``
    pair per aggregation→GEMM site the fused kernel covers (the Z-update
    targets — NOT the W-update line-search aggregates, which both paths
    materialise).  Unfused, every site writes its aggregate to HBM and
    the GEMM reads it back: 2·rows·c_in·itemsize each.  Fused, the
    aggregate lives in VMEM scratch: zero HBM bytes — only the GEMM
    output (identical in both paths) ever lands.
    """
    unfused = sum(2 * agg_rows * c_in * itemsize for c_in, _ in site_dims)
    gemm_out = sum(agg_rows * c_out * itemsize for _, c_out in site_dims)
    return {"agg_rows": int(agg_rows),
            "sites": len(list(site_dims)),
            "itemsize": int(itemsize),
            "unfused_intermediate_bytes": int(unfused),
            "fused_intermediate_bytes": 0,
            "gemm_out_bytes": int(gemm_out)}


def analytic_hbm_bytes(cfg, shape, step: str, chips: int,
                       model_shards: int = 16) -> float:
    """Algorithmic minimum HBM traffic per chip per step (roofline floor).

    The census HBM proxy is an *upper* bound — CPU fusion granularity is
    finer than TPU's, so logical buffers are counted at more boundaries.
    This floor counts: param reads (+grad/optimizer traffic for train),
    residual-stream activations at layer granularity, logits/CE passes and
    decode-cache reads.  §Roofline reports both bounds.
    """
    dt = 2 if cfg.dtype == "bfloat16" else 4
    p_bytes = cfg.param_count() * dt / chips
    d = cfg.d_model
    if step == "decode":
        tokens = shape.global_batch            # one per stream
        # cache read is the dominant decode traffic
        if cfg.arch_type == "ssm":
            s_cfg = cfg.ssm
            d_in = s_cfg.expand * d
            cache = (shape.global_batch * cfg.num_layers *
                     (d_in // s_cfg.head_dim) * s_cfg.head_dim *
                     s_cfg.d_state * dt)
        elif cfg.hybrid is not None:
            w = cfg.hybrid.lru_width or d
            n_attn = cfg.num_layers // len(cfg.hybrid.pattern)
            cache = shape.global_batch * (
                cfg.num_layers * w * 4 +        # recurrent states (f32)
                n_attn * min(shape.seq_len, cfg.hybrid.local_window) *
                cfg.num_kv_heads * cfg.resolved_head_dim * 2 * dt)
        elif cfg.mla is not None:
            eff = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            cache = (shape.global_batch * cfg.num_layers * eff *
                     (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * dt)
        else:
            eff = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            layers = cfg.num_decoder_layers if cfg.is_encoder_decoder \
                else cfg.num_layers
            cache = (shape.global_batch * layers * eff *
                     cfg.num_kv_heads * cfg.resolved_head_dim * 2 * dt)
        # active params read once (MoE reads only routed experts)
        act_p = cfg.active_param_count() * dt / chips
        return act_p + cache / chips + tokens * d * dt * 10
    tokens_per_chip = shape.global_batch * shape.seq_len / chips * 16  # model-dim sharding keeps activations on all model shards
    layers = cfg.num_layers + (cfg.num_decoder_layers or 0)
    act = tokens_per_chip * d * dt * layers * (30 if step == "train" else 10)
    logits = (shape.global_batch * shape.seq_len * cfg.vocab_size * 4 /
              chips * (4 if step == "train" else 0.01))
    if step == "train":
        accum = max(cfg.grad_accum, 1)
        return p_bytes * (2 * accum + 3) + act + logits
    return p_bytes + act + logits


def model_flops(cfg, shape, step: str) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train) / 2·N·D (inference)."""
    n = cfg.active_param_count()
    if step == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if step == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch
