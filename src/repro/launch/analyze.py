"""CLI: run the invariant linter over the benchmark trainer configs.

Builds each benchmark trainer (transport x pad-mode on the compressed
layout, plus the dense baseline in full mode), compiles its step on a
4-shard host mesh, runs the ``repro.analysis`` rule registry against the
trainer's own host-side expectations, and writes a JSON report.  Exit
status 1 if any error-severity finding survives its waivers — CI fails
the build on that.

    PYTHONPATH=src python src/repro/launch/analyze.py --quick
    PYTHONPATH=src python src/repro/launch/analyze.py --out report.json

The device-count flag must be set before jax initialises (a 1-shard mesh
compiles no real collectives, which would make every transport rule
vacuous), so jax/repro imports happen inside ``main`` after the env is
prepared.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

N_SHARDS = 4

# the four benchmark transport x pad-mode configs (--quick and CI);
# full mode adds the dense baseline (the dense-adjacency rule is waived
# there — that config IS the dense layout) and the bf16 wire/store path
QUICK_CONFIGS = [
    {"name": "p2p_global", "transport": "p2p", "pad_mode": "global"},
    {"name": "p2p_bucketed", "transport": "p2p", "pad_mode": "bucketed"},
    {"name": "allgather_global", "transport": "allgather",
     "pad_mode": "global"},
    {"name": "allgather_bucketed", "transport": "allgather",
     "pad_mode": "bucketed"},
    # packed resident state: memory/packed-resident-state proves the
    # compiled step holds no blocked row stack taller than r_pad
    {"name": "p2p_packed", "transport": "p2p", "pad_mode": "bucketed",
     "packed": True},
    {"name": "p2p_packed_overlap", "transport": "p2p",
     "pad_mode": "bucketed", "packed": True, "overlap": True},
    # stochastic minibatching: the collective/permute-schedule rule proves
    # the compiled sampled step's ppermute pairs are exactly the
    # restricted sub-plan's — no collective touches an unsampled shard pair
    {"name": "p2p_minibatch", "transport": "p2p", "pad_mode": "bucketed",
     "packed": True, "batch_fraction": 0.5, "stale_decay": 0.5},
    # fused aggregation→Z-update: memory/fused-no-intermediate proves the
    # compiled step hands no aggregated (k, n_pad, C) stack to a GEMM
    # beyond the W-update line-search allowance, and the pallas VMEM rule
    # covers the fused spec's scratch-resident aggregate
    {"name": "p2p_fused", "transport": "p2p", "pad_mode": "bucketed",
     "packed": True, "fused": True},
]
FULL_CONFIGS = QUICK_CONFIGS + [
    {"name": "dense_allgather", "transport": "allgather",
     "pad_mode": "global", "compressed": False},
    {"name": "p2p_bf16", "transport": "p2p", "pad_mode": "bucketed",
     "comm_bf16": True, "adjacency_bf16": True},
]


# serving-engine programs (repro.serve): the steady-state hit path must
# compile with zero collectives and nothing full-graph-sized (a hit
# touches one community block + one request-row vector); the miss-path
# halo kernel legitimately reads the Σ-bucket-rows plane but must still
# be collective-free (single-device recompute)
SERVE_CONFIGS = ["serve_hit", "serve_halo"]


def _ensure_devices() -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={N_SHARDS}"
        ).strip()


def _build_trainer(spec: dict):
    import jax

    from repro.core import gcn, graph
    from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
    from repro.core.subproblems import ADMMConfig
    from repro.util.compat import make_mesh

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
        size_skew=0.8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    mesh = make_mesh((N_SHARDS,), (AXIS,),
                     devices=jax.devices()[:N_SHARDS])
    # the spec dicts ARE TrainerConfig kwargs (single source of truth);
    # only the compressed default differs from the dataclass default
    kw = {k: v for k, v in spec.items() if k != "name"}
    kw.setdefault("compressed", True)
    return ParallelADMMTrainer(cfg, admm, g, num_parts=8, seed=0,
                               part=part, mesh=mesh,
                               config=TrainerConfig(**kw))


def run_configs(configs: list[dict]) -> list:
    from repro import analysis

    # the dense baseline legitimately holds the dense block tensor; the
    # rule is already gated on dense_adjacency_allowed, the waiver here
    # documents the intent in the report
    waivers = (analysis.Waiver(
        "memory/no-dense-adjacency",
        "the dense baseline IS the dense layout",
        when={"compressed": False}),
               analysis.Waiver(
        "pallas/tile-alignment",
        "the packed ELL kernel contracts in 8-row steps by design — "
        "bucket sizes and plane offsets are multiples of the 8-row "
        "tile quantum, so the ell_blocks lane dim is 8, not 128",
        when={"state_packed": True}))
    reports = []
    for spec in configs:
        tr = _build_trainer(spec)
        reports.append(analysis.analyze_trainer(
            tr, config=spec["name"], waivers=waivers))
    return reports


def _build_server():
    import jax

    from repro.core import gcn, graph
    from repro.serve import CommunityServer, ServeConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
        size_skew=0.8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed", num_parts=8)
    ws = gcn.init_weights(cfg, jax.random.key(0))
    return CommunityServer(cfg, layout, ws, g.features, ServeConfig())


def run_serving_configs(names=None) -> list:
    from repro import analysis

    picked = set(names) if names else set(SERVE_CONFIGS)
    srv = _build_server()
    reports = []
    if "serve_hit" in picked:
        hlo = srv.hit_path_lowered(bucket=64).compile().as_text()
        reports.append(analysis.analyze_hlo(
            hlo, expectations={
                "expect_zero_collectives": True,
                "full_graph_rows": int(srv.dl.plane_rows),
            }, config="serve_hit"))
    if "serve_halo" in picked:
        hlo = srv.halo_path_lowered(layer=1).compile().as_text()
        reports.append(analysis.analyze_hlo(
            hlo, expectations={"expect_zero_collectives": True},
            config="serve_halo"))
    return reports


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="invariant linter over the benchmark trainer configs")
    ap.add_argument("--quick", action="store_true",
                    help="the four transport x pad-mode configs only")
    ap.add_argument("--config", action="append", default=None,
                    help="run only the named config(s)")
    ap.add_argument("--out", default="BENCH_analysis.json",
                    help="JSON report path")
    args = ap.parse_args(argv)

    _ensure_devices()
    configs = QUICK_CONFIGS if args.quick else FULL_CONFIGS
    serve_names = list(SERVE_CONFIGS)
    if args.config:
        picked = set(args.config)
        unknown = picked - {c["name"] for c in configs} - set(SERVE_CONFIGS)
        if unknown:
            ap.error(f"unknown config(s): {sorted(unknown)}")
        configs = [c for c in configs if c["name"] in picked]
        serve_names = [n for n in SERVE_CONFIGS if n in picked]

    reports = run_configs(configs)
    if serve_names:
        reports.extend(run_serving_configs(serve_names))
    n_err = 0
    for rep in reports:
        print(rep.summary())
        n_err += len(rep.errors())
    payload = {"n_shards": N_SHARDS,
               "errors": n_err,
               "reports": [r.to_dict() for r in reports]}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    print(f"wrote {args.out}: {len(reports)} config(s), "
          f"{n_err} error finding(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    os.pardir, os.pardir))
    sys.exit(main())
