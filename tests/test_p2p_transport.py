"""Neighbour-only ppermute transport: round-schedule correctness, wire-byte
invariants, and p2p vs allgather trainer parity on a real 2-shard mesh.

The schedule is host-side static (messages.NeighborExchange); the parity
test runs in a subprocess so XLA can be launched with 2 host devices, and
additionally proves from the compiled HLO that the p2p step contains no
all-gather op (no (M, n_pad, C) payload is ever materialised) while moving
fewer collective bytes than the allgather oracle.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import graph, messages
from repro.sharding.partition import ring_round_coloring


@pytest.fixture(scope="module", params=[2, 4])
def plan_case(request):
    n_shards = request.param
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=8, nodes_per_part=12, attach=2, seed=4, feat_dim=8)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True)
    plan = messages.build_neighbor_exchange(layout.neighbor_mask, n_shards,
                                            layout.n_pad)
    return layout, plan, n_shards


def _deliveries(plan):
    """(dst_shard, global_id) pairs the schedule actually transmits."""
    k = plan.lanes_per_shard
    out = []
    for rnd in plan.rounds:
        for src, dst in rnd.pairs:
            for t in range(rnd.rows_pad):
                slot = int(rnd.recv_slot[dst, t])
                if slot < plan.r_pad:      # real row, not round padding
                    gid = src * k + int(rnd.send_idx[src, t])
                    out.append((dst, gid, slot))
    return out


def test_schedule_covers_every_ell_edge_exactly_once(plan_case):
    """Every cross-shard ELL neighbour edge is delivered exactly once, to
    the slot the localized indices read; same-shard edges never hit the
    wire."""
    layout, plan, n_shards = plan_case
    csr = layout.compress()
    k = plan.lanes_per_shard
    deliveries = _deliveries(plan)
    seen = {}
    for dst, gid, slot in deliveries:
        assert (dst, gid) not in seen, f"duplicate delivery {(dst, gid)}"
        seen[(dst, gid)] = slot
        assert gid // k != dst, "own-shard rows must not be wired"
        # delivered to the slot the receive buffer maps this id to
        assert plan.slot_of(dst)[gid] == slot

    # required = every masked ELL edge, lifted to (shard, source community)
    required = set()
    for m in range(layout.num_parts):
        for d in np.flatnonzero(np.asarray(csr.ell_mask[m]) > 0):
            r = int(csr.ell_indices[m, d])
            if r // k != m // k:
                required.add((m // k, r))
            else:
                # resident rows are served locally from own_slots
                assert r in plan.needed_ids[m // k]
    assert set(seen) == required

    # localized indices stay inside the receive buffer and invert correctly
    local = plan.localize_indices(csr.ell_indices, csr.ell_mask)
    assert local.max() < plan.r_pad
    for m in range(layout.num_parts):
        ids = plan.needed_ids[m // k]
        for d in np.flatnonzero(np.asarray(csr.ell_mask[m]) > 0):
            assert ids[local[m, d]] == int(csr.ell_indices[m, d])


def test_rounds_are_partial_permutations(plan_case):
    _, plan, n_shards = plan_case
    for rnd in plan.rounds:
        srcs = [s for s, _ in rnd.pairs]
        dsts = [d for _, d in rnd.pairs]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        for src, dst in rnd.pairs:
            assert (dst - src) % n_shards == rnd.offset


def test_ring_round_coloring_rejects_bad_input():
    with pytest.raises(ValueError):
        ring_round_coloring([(0, 0)], 2)
    with pytest.raises(ValueError):
        ring_round_coloring([(0, 3)], 2)
    rounds = ring_round_coloring([(0, 1), (1, 0), (0, 2)], 4)
    assert set(rounds) == {1, 2, 3}


def test_wire_byte_invariant(plan_case):
    """p2p wire_bytes ≤ full_bytes, == true rows + round padding, and the
    scheduled true rows never exceed the mask-derived needed volume."""
    layout, plan, n_shards = plan_case
    dims = [16, 8]
    stats = messages.gather_bytes(layout.neighbor_mask, layout.n_pad, dims)
    stats.update(messages.exchange_bytes(plan, dims))
    messages.verify_transport_bytes(stats)      # must not raise
    assert stats["wire_bytes"] <= stats["full_bytes"]
    assert stats["wire_bytes"] == (stats["p2p_needed_bytes"]
                                   + stats["padding_bytes"])
    # padding included, the schedule stays within the mask-derived need
    assert stats["wire_bytes"] <= stats["needed_bytes"]
    assert stats["wire_bytes"] > 0              # cross-shard edges exist
    # the whole point: the schedule moves less than the all-gather
    assert stats["wire_bytes"] < stats["full_bytes"]

    bad = dict(stats)
    bad["padding_bytes"] += 1
    with pytest.raises(ValueError):
        messages.verify_transport_bytes(bad)
    bad = dict(stats)
    bad["wire_bytes"] = bad["full_bytes"] + 1
    with pytest.raises(ValueError):
        messages.verify_transport_bytes(bad)


def test_verify_transport_multi_lane_padding_is_soft():
    """On multi-lane shards round padding may exceed the mask slack on
    skewed topologies — that must be recorded (wire_within_needed=False),
    not raised, or legitimate compressed trainers become unconstructible.
    At k=1 padding is impossible by construction, so there it raises."""
    base = {"full_bytes": 1000, "needed_bytes": 500,
            "p2p_needed_bytes": 400, "padding_bytes": 200,
            "wire_bytes": 600, "lanes_per_shard": 2}
    out = messages.verify_transport_bytes(dict(base))
    assert out["wire_within_needed"] is False
    with pytest.raises(ValueError):
        messages.verify_transport_bytes(dict(base, lanes_per_shard=1))
    ok = messages.verify_transport_bytes(
        dict(base, padding_bytes=0, wire_bytes=400, lanes_per_shard=1))
    assert ok["wire_within_needed"] is True


def test_trainer_records_and_verifies_p2p_stats():
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    tr = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                             compressed=True)
    assert tr.transport == "p2p"
    assert tr.comm_stats["transport"] == "p2p"
    assert tr.comm_stats["wire_bytes"] <= tr.comm_stats["needed_bytes"]
    ag = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                             compressed=True, transport="allgather")
    assert ag.comm_stats["wire_bytes"] == ag.comm_stats["full_bytes"]
    with pytest.raises(ValueError):
        ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            transport="p2p")            # dense + p2p
    with pytest.raises(ValueError):
        ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            compressed=True, transport="carrier-pigeon")


_P2P_WORKER = r"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import gcn, graph, messages
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.launch import roofline
from repro.util import shard_map
from repro.util.compat import make_mesh
from jax.sharding import PartitionSpec as P

N_SHARDS = 4
assert len(jax.devices()) >= N_SHARDS, jax.devices()
g, part = graph.synthetic_powerlaw_communities(
    num_parts=12, nodes_per_part=12, attach=1, seed=0, feat_dim=8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh2 = make_mesh((N_SHARDS,), (AXIS,), devices=jax.devices()[:N_SHARDS])

# --- raw exchange == the needed rows of an all-gather, on real devices ---
layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                      compressed=True)
plan = messages.build_neighbor_exchange(layout.neighbor_mask, N_SHARDS,
                                        layout.n_pad)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(12, layout.n_pad, 8)).astype(np.float32))
ex = shard_map(lambda v: messages.exchange_neighbors(plan, v, AXIS),
               mesh=mesh2, in_specs=(P(AXIS),), out_specs=P(AXIS),
               check_rep=False)
bufs = np.asarray(jax.jit(ex)(x)).reshape(N_SHARDS, plan.r_pad,
                                          layout.n_pad, 8)
for s in range(N_SHARDS):
    ids = plan.needed_ids[s]
    for slot, gid in enumerate(ids):
        np.testing.assert_allclose(bufs[s, slot], np.asarray(x[gid]),
                                   rtol=0, atol=0)
    # slots past the shard's needed set stay zero
    for slot in range(len(ids), plan.r_pad):
        assert np.abs(bufs[s, slot]).max() == 0.0
print("EXCHANGE_OK")

# --- trainer parity: p2p vs allgather, 3 iterations, W/Z/U + Lagrangian ---
p2p = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh2, compressed=True, transport="p2p")
ag = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                         mesh=mesh2, compressed=True, transport="allgather")
assert p2p.transport == "p2p" and ag.transport == "allgather"
for _ in range(3):
    p2p.step(); ag.step()
for za, zp in zip(ag.state.zs, p2p.state.zs):
    np.testing.assert_allclose(np.asarray(za), np.asarray(zp),
                               rtol=2e-4, atol=2e-5)
for wa, wp in zip(ag.state.weights, p2p.state.weights):
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wp),
                               rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(np.asarray(ag.state.u), np.asarray(p2p.state.u),
                           rtol=2e-4, atol=2e-5)
lag_p, lag_a = float(p2p._lagrangian(p2p.state)), float(ag._lagrangian(ag.state))
assert abs(lag_p - lag_a) <= 1e-4 * max(1.0, abs(lag_a)), (lag_p, lag_a)
print("PARITY_OK")

# --- HLO proof: the p2p step materialises no gathered payload ---
hlo_p2p = p2p._step.lower(p2p.state).compile().as_text()
hlo_ag = ag._step.lower(ag.state).compile().as_text()
assert "all-gather" not in hlo_p2p, "p2p step still all-gathers"
assert "collective-permute" in hlo_p2p
assert "all-gather" in hlo_ag
c_p2p = roofline.hlo_census(hlo_p2p).collective_bytes
c_ag = roofline.hlo_census(hlo_ag).collective_bytes
assert 0 < c_p2p < c_ag, (c_p2p, c_ag)
print(f"WIRE_OK p2p={c_p2p} allgather={c_ag}")

# --- bf16 wire path stays close ---
b16 = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh2, compressed=True, transport="p2p",
                          comm_bf16=True)
for _ in range(2):
    b16.step()
ref = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh2, compressed=True, transport="p2p")
for _ in range(2):
    ref.step()
for zb, zr in zip(b16.state.zs, ref.state.zs):
    np.testing.assert_allclose(np.asarray(zb), np.asarray(zr),
                               rtol=0.05, atol=0.05)
print("BF16_OK")
"""


def test_p2p_parity_on_multi_shard_mesh():
    """p2p vs allgather on a real 4-shard host mesh (subprocess: XLA locks
    the device count at first init): identical W/Z/U and Lagrangian after 3
    iterations, raw exchange delivers exactly the needed rows, and the
    compiled p2p HLO contains collective-permutes but no all-gather while
    moving fewer collective bytes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _P2P_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("EXCHANGE_OK", "PARITY_OK", "WIRE_OK", "BF16_OK"):
        assert tag in out.stdout, out.stdout
