"""Neighbour-only ppermute transport: round-schedule correctness, wire-byte
invariants, and p2p vs allgather trainer parity on a real 2-shard mesh.

The schedule is host-side static (messages.NeighborExchange); the parity
test runs in a subprocess so XLA can be launched with 2 host devices, and
additionally proves from the compiled HLO that the p2p step contains no
all-gather op (no (M, n_pad, C) payload is ever materialised) while moving
fewer collective bytes than the allgather oracle.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import graph, messages
from repro.sharding.partition import ring_round_coloring


@pytest.fixture(scope="module", params=[(2, False), (4, False),
                                        (2, True), (4, True)])
def plan_case(request):
    """Whole-block plans on the uniform graph and row-exact plans on a
    size-skewed bucketed layout — the schedule tests hold for both."""
    n_shards, row_exact = request.param
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=8, nodes_per_part=12, attach=2, seed=4, feat_dim=8,
        size_skew=0.8 if row_exact else 0.0)
    layout = graph.build_community_layout(
        g.num_nodes, g.edges, part, compressed=True,
        pad_mode="bucketed" if row_exact else "global")
    plan = messages.build_neighbor_exchange(
        layout.neighbor_mask, n_shards, layout.n_pad,
        sizes=layout.sizes if row_exact else None)
    assert plan.row_exact == row_exact
    return layout, plan, n_shards


def _deliveries(plan):
    """(dst_shard, global_id, slot) triples the schedule transmits.

    Rows travel at node granularity: for every delivered community the
    helper additionally asserts that exactly its wired rows (true size on
    row-exact plans, all n_pad otherwise) arrive, each at the receive-
    buffer row its sender packed it for."""
    k, n = plan.lanes_per_shard, plan.n_pad
    rows_seen: dict[tuple, set] = {}
    for rnd in plan.rounds:
        for src, dst in rnd.pairs:
            for t in range(rnd.rows_pad):
                flat = int(rnd.recv_slot[dst, t])
                if flat >= plan.r_pad * n:   # round padding, dropped
                    continue
                slot, row = divmod(flat, n)
                lane, srow = divmod(int(rnd.send_idx[src, t]), n)
                assert srow == row, "send row misaligned with receive row"
                key = (dst, src * k + lane, slot)
                dup = rows_seen.setdefault(key, set())
                assert row not in dup, f"row {row} delivered twice: {key}"
                dup.add(row)
    out = []
    for (dst, gid, slot), rows in rows_seen.items():
        assert rows == set(range(plan.sizes[gid])), \
            f"community {gid} wired rows {sorted(rows)} != its true size"
        out.append((dst, gid, slot))
    return out


def test_schedule_covers_every_ell_edge_exactly_once(plan_case):
    """Every cross-shard ELL neighbour edge is delivered exactly once, to
    the slot the localized indices read; same-shard edges never hit the
    wire."""
    layout, plan, n_shards = plan_case
    csr = layout.compress()
    k = plan.lanes_per_shard
    deliveries = _deliveries(plan)
    seen = {}
    for dst, gid, slot in deliveries:
        assert (dst, gid) not in seen, f"duplicate delivery {(dst, gid)}"
        seen[(dst, gid)] = slot
        assert gid // k != dst, "own-shard rows must not be wired"
        # delivered to the slot the receive buffer maps this id to
        assert plan.slot_of(dst)[gid] == slot

    # required = every masked ELL edge, lifted to (shard, source community)
    required = set()
    for m in range(layout.num_parts):
        for d in np.flatnonzero(np.asarray(csr.ell_mask[m]) > 0):
            r = int(csr.ell_indices[m, d])
            if r // k != m // k:
                required.add((m // k, r))
            else:
                # resident rows are served locally from own_slots
                assert r in plan.needed_ids[m // k]
    assert set(seen) == required

    # localized indices stay inside the receive buffer and invert correctly
    local = plan.localize_indices(csr.ell_indices, csr.ell_mask)
    assert local.max() < plan.r_pad
    for m in range(layout.num_parts):
        ids = plan.needed_ids[m // k]
        for d in np.flatnonzero(np.asarray(csr.ell_mask[m]) > 0):
            assert ids[local[m, d]] == int(csr.ell_indices[m, d])


def test_rounds_are_partial_permutations(plan_case):
    """Each round is a partial permutation (the lax.ppermute contract).
    The colour index carries no ring-offset meaning anymore — the edge
    colouring packs messages of different offsets into one round."""
    _, plan, n_shards = plan_case
    for rnd in plan.rounds:
        srcs = [s for s, _ in rnd.pairs]
        dsts = [d for _, d in rnd.pairs]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)


def test_ring_round_coloring_rejects_bad_input():
    with pytest.raises(ValueError):
        ring_round_coloring([(0, 0)], 2)
    with pytest.raises(ValueError):
        ring_round_coloring([(0, 3)], 2)
    rounds = ring_round_coloring([(0, 1), (1, 0), (0, 2)], 4)
    # Δ = max degree = 2 (node 0 sends twice): exactly 2 colours, packed
    # contiguously from 0 — the historic ring-offset grouping burned a
    # round per distinct (dst-src) offset (here {1, 2, 3})
    assert rounds == {0: [(0, 1), (1, 0)], 1: [(0, 2)]}


def test_edge_coloring_is_degree_optimal():
    """König: the schedule always uses exactly Δ = max(out-degree,
    in-degree) rounds — the information-theoretic floor, since a shard can
    send (receive) at most one message per ppermute round."""
    rng = np.random.default_rng(7)
    for _ in range(100):
        n = int(rng.integers(2, 12))
        cand = [(u, v) for u in range(n) for v in range(n) if u != v]
        take = rng.random(len(cand)) < rng.uniform(0.1, 0.9)
        edges = [e for e, t in zip(cand, take) if t]
        if not edges:
            continue
        rounds = ring_round_coloring(edges, n)
        out_deg = np.zeros(n, int)
        in_deg = np.zeros(n, int)
        for u, v in edges:
            out_deg[u] += 1
            in_deg[v] += 1
        delta = max(out_deg.max(), in_deg.max())
        assert sorted(rounds) == list(range(len(rounds)))
        assert len(rounds) == delta
        assert sorted(e for grp in rounds.values() for e in grp) \
            == sorted(edges)
        for grp in rounds.values():
            assert len({u for u, _ in grp}) == len(grp)
            assert len({v for _, v in grp}) == len(grp)


def test_coloring_beats_ring_offsets_on_m32_powerlaw():
    """The round count the colouring buys on the benchmark topology: at
    M=32 communities over 16 shards (k=2) on the skewed power-law graph,
    the shard message graph has Δ = 7 but 15 distinct ring offsets — the
    offset grouping would burn 15 ppermute rounds where 7 suffice."""
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=32, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
        size_skew=0.9)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True)
    n_shards, k = 16, 2
    needed, _ = graph.shard_neighbor_graph(
        np.asarray(layout.neighbor_mask, bool), n_shards)
    edges = sorted({(int(r) // k, s) for s in range(n_shards)
                    for r in needed[s] if int(r) // k != s})
    rounds = ring_round_coloring(edges, n_shards)
    ring_offsets = len({(v - u) % n_shards for u, v in edges})
    out_deg = np.bincount([u for u, _ in edges], minlength=n_shards)
    in_deg = np.bincount([v for _, v in edges], minlength=n_shards)
    delta = int(max(out_deg.max(), in_deg.max()))
    assert len(rounds) == delta == 7
    assert ring_offsets == 15
    assert len(rounds) < ring_offsets


def test_wire_byte_invariant(plan_case):
    """p2p wire_bytes ≤ full_bytes, == true rows + round padding, and the
    scheduled true rows never exceed the mask-derived needed volume."""
    layout, plan, n_shards = plan_case
    dims = [16, 8]
    stats = messages.gather_bytes(layout.neighbor_mask, layout.n_pad, dims)
    stats.update(messages.exchange_bytes(plan, dims))
    messages.verify_transport_bytes(stats)      # must not raise
    assert stats["wire_bytes"] <= stats["full_bytes"]
    assert stats["wire_bytes"] == (stats["p2p_needed_bytes"]
                                   + stats["padding_bytes"])
    # the scheduled true rows never exceed the mask-derived need, and the
    # padding-included bound is recorded (hard only for whole-block plans)
    assert stats["p2p_needed_bytes"] <= stats["needed_bytes"]
    assert stats["wire_within_needed"] == \
        (stats["wire_bytes"] <= stats["needed_bytes"])
    if not plan.row_exact:
        assert stats["wire_within_needed"]
    else:
        # row-exact: strictly fewer true rows than the whole-block plan
        whole = messages.exchange_bytes(messages.build_neighbor_exchange(
            layout.neighbor_mask, n_shards, layout.n_pad), dims)
        assert stats["p2p_needed_bytes"] < whole["p2p_needed_bytes"]
        assert stats["wire_bytes"] < whole["wire_bytes"]
    assert stats["wire_bytes"] > 0              # cross-shard edges exist
    # the whole point: the schedule moves less than the all-gather
    assert stats["wire_bytes"] < stats["full_bytes"]

    bad = dict(stats)
    bad["padding_bytes"] += 1
    with pytest.raises(ValueError):
        messages.verify_transport_bytes(bad)
    bad = dict(stats)
    bad["wire_bytes"] = bad["full_bytes"] + 1
    with pytest.raises(ValueError):
        messages.verify_transport_bytes(bad)


@pytest.mark.parametrize("n_shards,k,trials", [
    (2, 1, 50), (4, 1, 50), (2, 2, 50), (2, 3, 50), (4, 2, 50), (4, 3, 50),
])
def test_wire_within_needed_fuzzed_topologies(n_shards, k, trials):
    """The ``wire_within_needed`` soft invariant, pinned down over fuzzed
    community topologies (300 total across the parametrization):

      * hard invariants never break: ``verify_transport_bytes`` passes,
        wire == true rows + round padding ≤ full, true rows ≤ needed;
      * the padding-included bound is soft EXACTLY when the round padding
        exceeds the mask slack — ``wire ≤ needed  ⟺  padding_bytes ≤
        needed_bytes − p2p_needed_bytes``, where the slack is the resident
        (own-lane) rows the masks count but the wire never carries plus
        per-shard deduplication of rows shared by co-hosted lanes;
      * at k=1 every scheduled row is a real row (``padding_bytes == 0``),
        so the bound can never be soft — the benchmark/CI regime.
    """
    m = n_shards * k
    rng = np.random.default_rng(1000 * n_shards + k)
    soft = 0
    for _ in range(trials):
        nbr = rng.random((m, m)) < rng.uniform(0.1, 0.9)
        nbr = nbr | nbr.T
        np.fill_diagonal(nbr, True)
        plan = messages.build_neighbor_exchange(nbr, n_shards, n_pad=8)
        stats = messages.gather_bytes(nbr, 8, [4])
        stats.update(messages.exchange_bytes(plan, [4]))
        out = messages.verify_transport_bytes(stats)   # hard: must not raise
        assert out["wire_bytes"] == (out["p2p_needed_bytes"]
                                     + out["padding_bytes"])
        assert out["wire_bytes"] <= out["full_bytes"]
        assert out["p2p_needed_bytes"] <= out["needed_bytes"]
        slack = out["needed_bytes"] - out["p2p_needed_bytes"]
        assert out["wire_within_needed"] == (out["padding_bytes"] <= slack)
        if k == 1:
            assert out["padding_bytes"] == 0 and out["wire_within_needed"]
        soft += not out["wire_within_needed"]
    if k == 1:
        assert soft == 0


def test_multilevel_wire_bytes_beat_bfs_kl_at_m32():
    """Partition quality IS wire volume: on the M=32 power-law benchmark
    graph the multilevel partition's NeighborExchange schedule moves no
    more bytes than the BFS+KL schedule (strictly fewer — its cut and ELL
    fan-in are strictly lower; benchmarks/check_bench.py guards the same
    inequality on the BENCH_speedup.json artifact in CI)."""
    g, _ = graph.synthetic_powerlaw_communities(
        32, nodes_per_part=32, attach=2, seed=0, feat_dim=8)
    wire = {}
    for method in ("bfs_kl", "multilevel"):
        part = graph.partition_graph(g.num_nodes, g.edges, 32, seed=0,
                                     method=method)
        layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                              compressed=True)
        plan = messages.build_neighbor_exchange(layout.neighbor_mask, 32,
                                                layout.n_pad)
        stats = messages.gather_bytes(layout.neighbor_mask, layout.n_pad,
                                      [64])
        stats.update(messages.exchange_bytes(plan, [64]))
        messages.verify_transport_bytes(stats)
        wire[method] = stats
    assert wire["multilevel"]["wire_bytes"] < wire["bfs_kl"]["wire_bytes"]
    assert (wire["multilevel"]["num_rounds"]
            <= wire["bfs_kl"]["num_rounds"])


def test_verify_transport_multi_lane_padding_is_soft():
    """On multi-lane shards round padding may exceed the mask slack on
    skewed topologies — that must be recorded (wire_within_needed=False),
    not raised, or legitimate compressed trainers become unconstructible.
    At k=1 padding is impossible by construction, so there it raises."""
    base = {"full_bytes": 1000, "needed_bytes": 500,
            "p2p_needed_bytes": 400, "padding_bytes": 200,
            "wire_bytes": 600, "lanes_per_shard": 2}
    out = messages.verify_transport_bytes(dict(base))
    assert out["wire_within_needed"] is False
    with pytest.raises(ValueError):
        messages.verify_transport_bytes(dict(base, lanes_per_shard=1))
    ok = messages.verify_transport_bytes(
        dict(base, padding_bytes=0, wire_bytes=400, lanes_per_shard=1))
    assert ok["wire_within_needed"] is True


def test_trainer_records_and_verifies_p2p_stats():
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    tr = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                             compressed=True)
    assert tr.transport == "p2p"
    assert tr.comm_stats["transport"] == "p2p"
    assert tr.comm_stats["wire_bytes"] <= tr.comm_stats["needed_bytes"]
    ag = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                             compressed=True, transport="allgather")
    assert ag.comm_stats["wire_bytes"] == ag.comm_stats["full_bytes"]
    with pytest.raises(ValueError):
        ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            transport="p2p")            # dense + p2p
    with pytest.raises(ValueError):
        ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            compressed=True, transport="carrier-pigeon")


_P2P_WORKER = r"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import gcn, graph, messages
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.launch import roofline
from repro.util import shard_map
from repro.util.compat import make_mesh
from jax.sharding import PartitionSpec as P

N_SHARDS = 4
assert len(jax.devices()) >= N_SHARDS, jax.devices()
g, part = graph.synthetic_powerlaw_communities(
    num_parts=12, nodes_per_part=12, attach=1, seed=0, feat_dim=8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh2 = make_mesh((N_SHARDS,), (AXIS,), devices=jax.devices()[:N_SHARDS])

# --- raw exchange == the needed rows of an all-gather, on real devices ---
layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                      compressed=True)
plan = messages.build_neighbor_exchange(layout.neighbor_mask, N_SHARDS,
                                        layout.n_pad)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(12, layout.n_pad, 8)).astype(np.float32))
ex = shard_map(lambda v: messages.exchange_neighbors(plan, v, AXIS),
               mesh=mesh2, in_specs=(P(AXIS),), out_specs=P(AXIS),
               check_rep=False)
bufs = np.asarray(jax.jit(ex)(x)).reshape(N_SHARDS, plan.r_pad,
                                          layout.n_pad, 8)
for s in range(N_SHARDS):
    ids = plan.needed_ids[s]
    for slot, gid in enumerate(ids):
        np.testing.assert_allclose(bufs[s, slot], np.asarray(x[gid]),
                                   rtol=0, atol=0)
    # slots past the shard's needed set stay zero
    for slot in range(len(ids), plan.r_pad):
        assert np.abs(bufs[s, slot]).max() == 0.0
print("EXCHANGE_OK")

# --- trainer parity: p2p vs allgather, 3 iterations, W/Z/U + Lagrangian ---
p2p = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh2, compressed=True, transport="p2p")
ag = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                         mesh=mesh2, compressed=True, transport="allgather")
assert p2p.transport == "p2p" and ag.transport == "allgather"
for _ in range(3):
    p2p.step(); ag.step()
for za, zp in zip(ag.state.zs, p2p.state.zs):
    np.testing.assert_allclose(np.asarray(za), np.asarray(zp),
                               rtol=2e-4, atol=2e-5)
for wa, wp in zip(ag.state.weights, p2p.state.weights):
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wp),
                               rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(np.asarray(ag.state.u), np.asarray(p2p.state.u),
                           rtol=2e-4, atol=2e-5)
lag_p, lag_a = float(p2p._lagrangian(p2p.state)), float(ag._lagrangian(ag.state))
assert abs(lag_p - lag_a) <= 1e-4 * max(1.0, abs(lag_a)), (lag_p, lag_a)
print("PARITY_OK")

# --- HLO proof via the analysis rules: the p2p step materialises no
#     gathered payload, its permute schedule matches the host plan, and
#     the full registry (memory, precision, donation) is clean ---
from repro import analysis
hlo_p2p = p2p._step.lower(p2p.state).compile().as_text()
hlo_ag = ag._step.lower(ag.state).compile().as_text()
rep = analysis.analyze_trainer(p2p, hlo_text=hlo_p2p, config="p2p-proof")
assert analysis.no_findings(rep, rule="collective/no-allgather-under-p2p")
assert analysis.no_findings(rep, rule="collective/permute-schedule")
assert analysis.no_findings(rep, rule="memory/no-dense-adjacency")
assert not rep.errors(), rep.summary()
rep_ag = analysis.analyze_trainer(ag, hlo_text=hlo_ag, config="ag-oracle")
assert not rep_ag.errors(), rep_ag.summary()
# deliberate break: the allgather program under the p2p expectations must
# trip exactly the rule that guards the transport contract
bad = analysis.analyze_hlo(
    hlo_ag, expectations=analysis.trainer_expectations(p2p))
assert bad.findings_for("collective/no-allgather-under-p2p"), \
    "linter missed the all-gather"
c_p2p = roofline.hlo_census(hlo_p2p).collective_bytes
c_ag = roofline.hlo_census(hlo_ag).collective_bytes
assert 0 < c_p2p < c_ag, (c_p2p, c_ag)
print(f"WIRE_OK p2p={c_p2p} allgather={c_ag}")

# --- bf16 wire path stays close ---
b16 = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh2, compressed=True, transport="p2p",
                          comm_bf16=True)
for _ in range(2):
    b16.step()
ref = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh2, compressed=True, transport="p2p")
for _ in range(2):
    ref.step()
for zb, zr in zip(b16.state.zs, ref.state.zs):
    np.testing.assert_allclose(np.asarray(zb), np.asarray(zr),
                               rtol=0.05, atol=0.05)
print("BF16_OK")
"""


_MULTILEVEL_WORKER = r"""
import jax
import numpy as np
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.serial import SerialADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

N_SHARDS = 4
assert len(jax.devices()) >= N_SHARDS, jax.devices()
g, _ = graph.synthetic_powerlaw_communities(
    num_parts=12, nodes_per_part=12, attach=1, seed=0, feat_dim=8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh = make_mesh((N_SHARDS,), (AXIS,), devices=jax.devices()[:N_SHARDS])

serial = SerialADMMTrainer(cfg, admm, g, seed=0)
ml = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, mesh=mesh,
                         compressed=True, partitioner="multilevel")
ag = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, mesh=mesh,
                         compressed=True, partitioner="multilevel",
                         transport="allgather")
assert ml.partitioner == "multilevel" and ml.transport == "p2p"
assert ml.comm_stats["partitioner"] == "multilevel"
assert ml.comm_stats["partition"]["edge_cut"] == ml.partition_stats["edge_cut"]
for _ in range(3):
    serial.step(); ml.step(); ag.step()

# -- serial parity: the partitioner only reshapes communication; the math
#    is the global Algorithm 1 either way --
for zs_, zp in zip(serial.state.zs, ml.state.zs):
    np.testing.assert_allclose(np.asarray(zs_),
                               ml.layout.unpack(np.asarray(zp)),
                               rtol=2e-3, atol=2e-4)
for ws, wp in zip(serial.state.weights, ml.state.weights):
    np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                               rtol=2e-3, atol=2e-4)
np.testing.assert_allclose(np.asarray(serial.state.u),
                           ml.layout.unpack(np.asarray(ml.state.u)),
                           rtol=2e-3, atol=2e-4)
lag_s = float(serial._lagr(serial.a_tilde, serial.z0, serial.labels,
                           serial.train_mask, serial.state))
lag_m = float(ml._lagrangian(ml.state))
assert abs(lag_s - lag_m) <= 1e-4 * max(1.0, abs(lag_s)), (lag_s, lag_m)
print("SERIAL_PARITY_OK")

# -- transport parity under the multilevel partition: p2p vs allgather
#    bit-compare on the same layout --
for za, zp in zip(ag.state.zs, ml.state.zs):
    np.testing.assert_allclose(np.asarray(za), np.asarray(zp),
                               rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(np.asarray(ag.state.u), np.asarray(ml.state.u),
                           rtol=2e-4, atol=2e-5)
print("TRANSPORT_PARITY_OK")

# -- and the multilevel layout still compiles to a gather-free p2p step
#    (the analysis rules prove it, plus schedule/memory/precision) --
from repro import analysis
rep = analysis.analyze_trainer(ml, config="multilevel-p2p")
assert analysis.no_findings(rep, rule="collective/no-allgather-under-p2p")
assert analysis.no_findings(rep, rule="collective/permute-schedule")
assert not rep.errors(), rep.summary()
print("HLO_OK")
"""


def test_multilevel_partition_trainer_invariance():
    """ParallelADMMTrainer(partitioner='multilevel') on a real 4-shard mesh
    matches the serial trainer's W/Z/U and Lagrangian to float tolerance
    after 3 iterations, and its p2p step matches the allgather oracle on
    the same layout — the partitioner choice changes only who talks to
    whom, never the optimization semantics."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _MULTILEVEL_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("SERIAL_PARITY_OK", "TRANSPORT_PARITY_OK", "HLO_OK"):
        assert tag in out.stdout, out.stdout


def test_p2p_parity_on_multi_shard_mesh():
    """p2p vs allgather on a real 4-shard host mesh (subprocess: XLA locks
    the device count at first init): identical W/Z/U and Lagrangian after 3
    iterations, raw exchange delivers exactly the needed rows, and the
    compiled p2p HLO contains collective-permutes but no all-gather while
    moving fewer collective bytes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _P2P_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("EXCHANGE_OK", "PARITY_OK", "WIRE_OK", "BF16_OK"):
        assert tag in out.stdout, out.stdout
