"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward + one train step + one decode step on CPU; asserts shapes and
finiteness. The FULL configs are exercised via the dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import InputShape
from repro.models.build import make_model

SMOKE_SHAPE = InputShape("smoke", seq_len=64, global_batch=2, step="train")


def _smoke_batch(model, key):
    cfg = model.cfg
    b, s = 2, 64
    rng = np.random.default_rng(0)
    if cfg.is_encoder_decoder:
        return {
            "frames": jnp.asarray(rng.normal(size=(b, 32, cfg.d_model))
                                  .astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                                  .astype(np.int32)),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                                   .astype(np.int32)),
        }
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                              .astype(np.int32)),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                               .astype(np.int32)),
    }
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, 16, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.d_model <= 512 and cfg.num_layers <= 5
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(model, jax.random.key(1))

    logits, aux, _ = jax.jit(model.forward)(params, batch)
    expect_s = batch["tokens"].shape[1]
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_state = model.init_optimizer().init(params)
    params2, opt_state, metrics = jax.jit(model.train_step)(
        params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, pair: acc or bool(pair),
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2),
        False)
    assert moved


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    caches = model.init_cache(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
    logits, caches = step(params, caches, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    logits2, caches = step(params, caches, tok + 1)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache position advanced where applicable
    flat = jax.tree_util.tree_leaves_with_path(caches)
    pos_leaves = [l for p, l in flat
                  if any(getattr(k, "key", None) == "pos" for k in p)]
    for leaf in pos_leaves:
        assert int(np.asarray(leaf).max()) == 2


@pytest.mark.parametrize("arch", ["gemma-2b", "qwen2-7b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_rolling_decode(arch):
    """Sliding-window (rolling) decode used by long_500k."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.arch_type not in ("ssm", "hybrid"):
        cfg = dataclasses.replace(cfg, sliding_window=16)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    caches = model.init_cache(1, 64, rolling=True)
    tok = jnp.zeros((1, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, rolling=True))
    for _ in range(3):
        logits, caches = step(params, caches, tok)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_counts_match_scale():
    """Full configs' analytic param counts are in the advertised ballpark."""
    expect = {
        "deepseek-v3-671b": (550e9, 800e9),
        "nemotron-4-15b": (12e9, 19e9),
        "deepseek-moe-16b": (13e9, 20e9),
        # assigned spec says 48L (Moonlight card is 27L) -> ~28B total;
        # we follow the assigned numbers exactly
        "moonshot-v1-16b-a3b": (25e9, 32e9),
        "qwen2-7b": (6e9, 9e9),
        "gemma-2b": (1.5e9, 3.5e9),
        "mamba2-1.3b": (1.0e9, 2.0e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "internvl2-2b": (1.5e9, 3e9),
        "seamless-m4t-medium": (0.8e9, 2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.12 * total          # ~37B active of 671B
