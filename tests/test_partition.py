"""Partitioner + community layout unit tests."""
import numpy as np
import pytest

from repro.core import graph


@pytest.fixture(scope="module")
def g():
    return graph.synthetic_sbm("amazon_photo_mini", seed=1)


def test_normalized_adjacency_symmetric_and_scaled(g):
    a = graph.normalized_adjacency(g.num_nodes, g.edges)
    assert np.allclose(a, a.T, atol=1e-6)
    # eigenvalues of (D+I)^{-1/2}(A+I)(D+I)^{-1/2} lie in [-1, 1]
    row_sums = np.abs(a).sum(axis=1)
    assert row_sums.max() <= np.sqrt(g.num_nodes)  # loose sanity
    # self-loop entries present
    assert (np.diag(a) > 0).all()


def test_partition_balanced_and_complete(g):
    m = 4
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    assert part.min() == 0 and part.max() == m - 1
    sizes = np.bincount(part, minlength=m)
    cap = int(np.ceil(g.num_nodes / m))
    assert (sizes <= cap).all() and (sizes > 0).all()


def test_partition_beats_random_cut(g):
    m = 3
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    rng = np.random.default_rng(0)
    rand = rng.integers(0, m, g.num_nodes)
    assert graph.edge_cut(g.edges, part) < graph.edge_cut(g.edges, rand)


def test_layout_blocks_reassemble_full_adjacency(g):
    m = 3
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    a_full = graph.normalized_adjacency(g.num_nodes, g.edges)
    # blocked SpMM == dense SpMM on a random feature matrix
    rng = np.random.default_rng(1)
    x = rng.normal(size=(g.num_nodes, 13)).astype(np.float32)
    x_blk = layout.pack(x)                        # (M, n_pad, 13)
    out_blk = np.einsum("mrip,rpc->mic", layout.a_blocks, x_blk)
    out = layout.unpack(out_blk)
    assert np.allclose(out, a_full @ x, atol=1e-4)


def test_layout_pack_unpack_roundtrip(g):
    part = graph.partition_graph(g.num_nodes, g.edges, 3, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    x = np.arange(g.num_nodes, dtype=np.float32)[:, None]
    assert np.array_equal(layout.unpack(layout.pack(x)), x)


def test_neighbor_mask_matches_blocks(g):
    part = graph.partition_graph(g.num_nodes, g.edges, 3, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    nonzero = np.abs(layout.a_blocks).sum(axis=(2, 3)) > 0
    assert (layout.neighbor_mask >= nonzero).all()


def test_partition_deterministic_golden(g):
    """The deque+seen-set BFS must reproduce the exact partitions the old
    list.pop(0) frontier produced (checksums captured before the switch):
    a node is assigned at its earliest enqueue position either way."""
    golden = {
        (3, 0): (1530, 165707, 6968),
        (4, 0): (2296, 796806, 6035),
        (3, 1): (1530, 185447, 6893),
        (6, 2): (3825, 890231, 8711),
    }
    for (m, seed), (tot, chk, cut) in golden.items():
        part = graph.partition_graph(g.num_nodes, g.edges, m, seed=seed)
        got = (int(part.sum()),
               int((part * np.arange(len(part))).sum() % 1000003),
               graph.edge_cut(g.edges, part))
        assert got == (tot, chk, cut), (m, seed, got)


def test_partition_scales_linearly_in_frontier():
    """BFS growth must not blow up on graphs where the old O(frontier) pop
    and duplicate re-enqueue were quadratic — a star-ish graph whose hub
    floods the frontier with every neighbour at once."""
    n = 20000
    hub_edges = np.stack([np.zeros(n - 1, np.int64),
                          np.arange(1, n, dtype=np.int64)], axis=1)
    ring = np.stack([np.arange(n, dtype=np.int64),
                     np.roll(np.arange(n, dtype=np.int64), -1)], axis=1)
    edges = np.concatenate([hub_edges, ring]).astype(np.int32)
    part = graph.partition_graph(n, edges, 4, seed=0, refine_iters=1)
    sizes = np.bincount(part, minlength=4)
    assert (sizes > 0).all() and sizes.max() <= int(np.ceil(n / 4))


def test_blockcsr_shard_slice_covers_all_rows():
    g2, part = graph.synthetic_powerlaw_communities(
        num_parts=6, nodes_per_part=16, attach=1, seed=0, feat_dim=4)
    layout = graph.build_community_layout(g2.num_nodes, g2.edges, part,
                                          compressed=True)
    csr = layout.compress()
    for n_shards in (1, 2, 3, 6):
        blocks = np.concatenate(
            [csr.shard_slice(s, n_shards)[0] for s in range(n_shards)])
        idx = np.concatenate(
            [csr.shard_slice(s, n_shards)[1] for s in range(n_shards)])
        np.testing.assert_array_equal(blocks, csr.ell_blocks)
        np.testing.assert_array_equal(idx, csr.ell_indices)
    with pytest.raises(ValueError):
        csr.shard_slice(0, 4)       # 6 rows don't split into 4 shards


GOLDEN_QUALITY = {
    # (graph, M): {method: (edge_cut, max_deg)} — exact values; a changed
    # cut means the partitioner changed behaviour, which must be a
    # deliberate decision (re-record the goldens), never silent drift.
    # Re-pinned when the FM gain-bucket refinement (hill-climb + best-
    # prefix rollback) replaced the positive-gain argsort passes: every
    # cut improved — powerlaw32 591→244 (the planted cut exactly),
    # powerlaw8 116→96, photo_mini M=3 4149→3836, M=4 4085→3878 — and no
    # max_deg got worse.  Re-pin again ONLY on improvement.
    ("powerlaw32", 32): {"bfs_kl": (1224, 24), "multilevel": (244, 13)},
    ("powerlaw8", 8): {"bfs_kl": (179, 6), "multilevel": (96, 5)},
    ("sbm_photo_mini", 3): {"bfs_kl": (6968, 3), "multilevel": (3836, 3)},
    ("sbm_photo_mini", 4): {"bfs_kl": (6035, 4), "multilevel": (3878, 4)},
}


def _quality_graph(name: str):
    if name == "powerlaw32":
        return graph.synthetic_powerlaw_communities(
            32, nodes_per_part=32, attach=2, seed=0, feat_dim=8)[0]
    if name == "powerlaw8":
        return graph.synthetic_powerlaw_communities(
            8, nodes_per_part=16, attach=1, seed=0, feat_dim=8)[0]
    return graph.synthetic_sbm("amazon_photo_mini", seed=1)


@pytest.mark.parametrize("name,m", sorted(GOLDEN_QUALITY))
def test_partition_quality_regression(name, m):
    """Multilevel must dominate BFS+KL on the benchmark graphs — cut no
    higher (strictly lower on the power-law M=32 acceptance graph), block
    max_deg no worse, strict balance cap — and both methods must reproduce
    the recorded golden cuts exactly so regressions fail loudly."""
    g = _quality_graph(name)
    got = {}
    for method in ("bfs_kl", "multilevel"):
        part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0,
                                     method=method)
        q = graph.partition_quality(g.num_nodes, g.edges, part, m)
        assert q["balance"] <= 1.0 + 1e-9, (method, q)
        got[method] = (q["edge_cut"], q["max_deg"])
    ml, kl = got["multilevel"], got["bfs_kl"]
    assert ml[0] <= kl[0], f"multilevel cut {ml[0]} above bfs_kl {kl[0]}"
    assert ml[1] <= kl[1], f"multilevel max_deg {ml[1]} above {kl[1]}"
    if name == "powerlaw32":            # the acceptance criterion is strict
        assert ml[0] < kl[0]
    assert got == GOLDEN_QUALITY[(name, m)], (
        f"partition quality drifted from the golden record: {got} != "
        f"{GOLDEN_QUALITY[(name, m)]} — if deliberate, re-record")


def test_partition_method_dispatch_rejects_unknown(g):
    with pytest.raises(ValueError):
        graph.partition_graph(g.num_nodes, g.edges, 3, method="metis5")


def test_partition_quality_matches_layout_max_deg(g):
    """partition_quality.max_deg must equal the BlockCSR ELL fan-in the
    partition induces — it is the cheap proxy the benchmarks report."""
    for method in ("bfs_kl", "multilevel"):
        part = graph.partition_graph(g.num_nodes, g.edges, 4, seed=0,
                                     method=method)
        q = graph.partition_quality(g.num_nodes, g.edges, part, 4)
        layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                              compressed=True)
        csr = layout.compress()
        assert q["max_deg"] == csr.max_deg
        assert q["nnz_blocks"] == layout.nnz_blocks


def test_sbm_statistics():
    g = graph.synthetic_sbm("amazon_photo_mini", seed=0)
    n, n_train, n_test, k, c0, _ = graph.DATASET_STATS["amazon_photo_mini"]
    assert g.num_nodes == n
    assert g.features.shape == (n, c0)
    assert int(g.train_mask.sum()) == n_train
    assert int(g.test_mask.sum()) == n_test
    assert not (g.train_mask & g.test_mask).any()
    assert g.num_classes == k
