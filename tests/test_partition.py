"""Partitioner + community layout unit tests."""
import numpy as np
import pytest

from repro.core import graph


@pytest.fixture(scope="module")
def g():
    return graph.synthetic_sbm("amazon_photo_mini", seed=1)


def test_normalized_adjacency_symmetric_and_scaled(g):
    a = graph.normalized_adjacency(g.num_nodes, g.edges)
    assert np.allclose(a, a.T, atol=1e-6)
    # eigenvalues of (D+I)^{-1/2}(A+I)(D+I)^{-1/2} lie in [-1, 1]
    row_sums = np.abs(a).sum(axis=1)
    assert row_sums.max() <= np.sqrt(g.num_nodes)  # loose sanity
    # self-loop entries present
    assert (np.diag(a) > 0).all()


def test_partition_balanced_and_complete(g):
    m = 4
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    assert part.min() == 0 and part.max() == m - 1
    sizes = np.bincount(part, minlength=m)
    cap = int(np.ceil(g.num_nodes / m))
    assert (sizes <= cap).all() and (sizes > 0).all()


def test_partition_beats_random_cut(g):
    m = 3
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    rng = np.random.default_rng(0)
    rand = rng.integers(0, m, g.num_nodes)
    assert graph.edge_cut(g.edges, part) < graph.edge_cut(g.edges, rand)


def test_layout_blocks_reassemble_full_adjacency(g):
    m = 3
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    a_full = graph.normalized_adjacency(g.num_nodes, g.edges)
    # blocked SpMM == dense SpMM on a random feature matrix
    rng = np.random.default_rng(1)
    x = rng.normal(size=(g.num_nodes, 13)).astype(np.float32)
    x_blk = layout.pack(x)                        # (M, n_pad, 13)
    out_blk = np.einsum("mrip,rpc->mic", layout.a_blocks, x_blk)
    out = layout.unpack(out_blk)
    assert np.allclose(out, a_full @ x, atol=1e-4)


def test_layout_pack_unpack_roundtrip(g):
    part = graph.partition_graph(g.num_nodes, g.edges, 3, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    x = np.arange(g.num_nodes, dtype=np.float32)[:, None]
    assert np.array_equal(layout.unpack(layout.pack(x)), x)


def test_neighbor_mask_matches_blocks(g):
    part = graph.partition_graph(g.num_nodes, g.edges, 3, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    nonzero = np.abs(layout.a_blocks).sum(axis=(2, 3)) > 0
    assert (layout.neighbor_mask >= nonzero).all()


def test_sbm_statistics():
    g = graph.synthetic_sbm("amazon_photo_mini", seed=0)
    n, n_train, n_test, k, c0, _ = graph.DATASET_STATS["amazon_photo_mini"]
    assert g.num_nodes == n
    assert g.features.shape == (n, c0)
    assert int(g.train_mask.sum()) == n_train
    assert int(g.test_mask.sum()) == n_test
    assert not (g.train_mask & g.test_mask).any()
    assert g.num_classes == k
