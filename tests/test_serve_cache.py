"""Deterministic serving-cache tests: LRU order, Zipf admission, stats.

The randomized counterparts (arbitrary op sequences against a shadow
model) live in tests/test_property.py; these pin the exact semantics the
engine relies on with hand-built sequences.
"""
import numpy as np
import pytest

from repro.serve import CacheStats, FrequencySketch, LRUCache


def test_capacity_bound_and_lru_eviction_order():
    c = LRUCache(3)
    for k in "abcd":
        assert c.put(k, k.upper())
    assert len(c) == 3
    # 'a' was least recently used -> evicted
    assert "a" not in c and c.keys() == ["b", "c", "d"]
    assert c.stats.evictions == 1


def test_get_refreshes_recency():
    c = LRUCache(3)
    for k in "abc":
        c.put(k, 0)
    assert c.get("a") == 0          # 'a' now most recent
    c.put("d", 0)                   # evicts 'b', not 'a'
    assert "a" in c and "b" not in c
    assert c.keys() == ["c", "a", "d"]


def test_put_overwrite_refreshes_without_eviction():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.put("a", 3)            # overwrite, no eviction
    assert len(c) == 2 and c.get("a") == 3
    assert c.stats.evictions == 0
    assert c.keys() == ["b", "a"]


def test_capacity_zero_disables():
    c = LRUCache(0)
    assert not c.put("a", 1)
    assert c.get("a") is None
    assert len(c) == 0
    assert c.stats.rejections == 1 and c.stats.misses == 1


def test_zipf_admission_refuses_cold_candidate():
    c = LRUCache(1, admission="zipf")
    for _ in range(5):
        c.get("hot")                # build frequency for the resident key
    c.put("hot", 1)
    # a single-touch candidate must not evict the hot resident
    c.get("cold")
    assert not c.put("cold", 2)
    assert "hot" in c and "cold" not in c
    assert c.stats.rejections == 1


def test_zipf_admission_admits_hotter_candidate():
    c = LRUCache(1, admission="zipf")
    c.get("old")
    c.put("old", 1)
    for _ in range(3):
        c.get("new")                # hotter than the resident
    assert c.put("new", 2)
    assert "new" in c and "old" not in c
    assert c.stats.evictions == 1


def test_contains_is_side_effect_free():
    c = LRUCache(2, admission="zipf")
    c.put("a", 1)
    c.put("b", 2)
    before = (c.stats.hits, c.stats.misses, c.keys())
    assert "a" in c and "z" not in c
    assert (c.stats.hits, c.stats.misses, c.keys()) == before


def test_invalidate_and_invalidate_where():
    c = LRUCache(8)
    for m in range(4):
        c.put((m, 1), m)
        c.put((m, 2), m)
    assert c.invalidate((0, 1))
    assert not c.invalidate((0, 1))     # already gone
    doomed = c.invalidate_where(lambda k: k[1] == 2)
    assert sorted(doomed) == [(m, 2) for m in range(4)]
    assert len(c) == 3
    assert c.stats.invalidations == 5


def test_clear_counts_invalidations():
    c = LRUCache(4)
    for k in "abc":
        c.put(k, 0)
    c.clear()
    assert len(c) == 0 and c.stats.invalidations == 3


def test_frequency_sketch_ages():
    s = FrequencySketch(sample=8)
    for _ in range(7):
        s.touch("a")
    assert s.estimate("a") == 7
    s.touch("b")                    # 8th touch triggers halving
    assert s.estimate("a") == 3     # 7 // 2
    assert s.estimate("b") == 0     # 1 // 2 -> dropped


def test_stats_hit_rate():
    st = CacheStats(hits=3, misses=1)
    assert st.lookups == 4 and st.hit_rate == 0.75
    assert st.as_dict()["hit_rate"] == 0.75
    st.reset()
    assert st.lookups == 0 and st.hit_rate == 0.0


def test_constructor_validation():
    with pytest.raises(ValueError, match="capacity"):
        LRUCache(-1)
    with pytest.raises(ValueError, match="admission"):
        LRUCache(2, admission="fifo")
    with pytest.raises(ValueError, match="sample"):
        FrequencySketch(sample=0)
