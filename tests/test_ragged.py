"""Ragged (size-aware) community padding: bucket scheme, blockify round
trips, pad accounting, row-exact exchange, the ragged-vs-global trainer
A/B, and the bf16 ELL block store.

The invariant under test everywhere: bucketed padding and row-exact wire
change what is PROCESSED and TRANSMITTED, never the math — trainers under
any pad scheme produce identical iterates, while ``comm_stats`` shows
pad_bytes/pad_flops/wire_bytes dropping.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gcn, graph, messages
from repro.core.parallel import ParallelADMMTrainer
from repro.core.subproblems import ADMMConfig


# ---------------------------------------------------------------------------
# bucket scheme
# ---------------------------------------------------------------------------

def test_pad_ladder_is_geometric_and_8_aligned():
    ladder = graph.pad_ladder(512)
    assert ladder[0] == 8
    assert all(v % 8 == 0 for v in ladder)
    ratios = [b / a for a, b in zip(ladder, ladder[1:])]
    assert max(ratios) <= 2.0 and min(ratios) > 1.0
    # the power-of-two-ish prefix is exactly the documented one
    assert ladder[:8] == [8, 16, 24, 32, 48, 64, 96, 128]


def test_bucket_pad_sizes_cases():
    sizes = [0, 1, 7, 8, 9, 24, 25, 33, 100, 200]
    out = graph.bucket_pad_sizes(sizes, n_pad=200)
    assert out.tolist() == [0, 8, 8, 8, 16, 24, 32, 48, 128, 200]
    # every nonempty community fits its bucket; buckets never exceed n_pad
    assert all(b >= s for s, b in zip(sizes, out) if b)
    assert out.max() <= 200
    # cap at n_pad: a size in the top bucket keeps the global pad
    assert graph.bucket_pad_sizes([40], n_pad=40).tolist() == [40]


@pytest.mark.parametrize("m,n_c,skew", [
    (100, 1, 2.0), (200, 1, 3.0), (32, 32, 1.0), (8, 2, 5.0),
])
def test_size_skew_extreme_params_keep_contract(m, n_c, skew):
    """The remainder correction must never drive a community size below 1,
    even when the min-size bumps overshoot the floor() undershoot (many
    tail communities at extreme skew): N stays M·nodes_per_part exactly."""
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=n_c, attach=1, seed=0, feat_dim=4, size_skew=skew)
    sizes = np.bincount(part, minlength=m)
    assert g.num_nodes == m * n_c
    assert sizes.sum() == m * n_c and (sizes >= 1).all()


@pytest.fixture(scope="module")
def skewed_layout():
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=8, nodes_per_part=24, attach=2, seed=0, feat_dim=8,
        size_skew=0.9)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    return g, layout


def test_bucketed_layout_row_counts(skewed_layout):
    g, layout = skewed_layout
    counts = layout.eff_row_counts()
    assert layout.pad_mode == "bucketed"
    assert (counts >= layout.sizes).all()
    assert (counts <= layout.n_pad).all()
    # skewed sizes ⇒ strictly less logical padding than the global scheme
    global_pad = layout.num_parts * layout.n_pad - int(layout.sizes.sum())
    assert 0 < layout.pad_rows < global_pad
    # the BlockCSR carries the same ragged metadata
    csr = layout.compress()
    rows, nbrs = csr.ell_row_counts()
    np.testing.assert_array_equal(rows, counts)
    # nbr counts are the row counts of the indexed community, zero on pads
    for m in range(layout.num_parts):
        for d in range(csr.max_deg):
            expect = counts[csr.ell_indices[m, d]] if csr.ell_mask[m, d] \
                else 0
            assert nbrs[m, d] == expect


def test_blocks_are_zero_outside_row_counts(skewed_layout):
    """The contract the kernel guards rely on: every stored block is zero
    outside its (row_counts[m], row_counts[r]) corner."""
    _, layout = skewed_layout
    counts = layout.eff_row_counts()
    for m in range(layout.num_parts):
        for r in range(layout.num_parts):
            blk = layout.a_blocks[m, r]
            assert np.abs(blk[counts[m]:, :]).sum() == 0.0
            assert np.abs(blk[:, counts[r]:]).sum() == 0.0


def test_blockify_roundtrip_and_size(skewed_layout):
    g, layout = skewed_layout
    rng = np.random.default_rng(0)
    x = rng.normal(size=(g.num_nodes, 5)).astype(np.float32)
    b = layout.blockify(x)
    # ragged total: Σ bucket rows — strictly below the M·n_pad pack
    assert b.shape[0] == int(layout.eff_row_counts().sum())
    assert b.shape[0] < layout.num_parts * layout.n_pad
    np.testing.assert_array_equal(layout.unblockify(b), x)
    # offsets partition the ragged rows
    offs = layout.row_offsets()
    assert offs[0] == 0 and offs[-1] == b.shape[0]


def test_blockify_empty_and_singleton_communities():
    """Forced num_parts keeps trailing/interior empty communities; blockify
    must round-trip with 0-row and 1-node communities present."""
    n = 7
    edges = np.array([[0, 1], [1, 2], [3, 4]], dtype=np.int32)
    part = np.array([0, 0, 0, 2, 2, 2, 4], dtype=np.int32)  # 1, 3 empty
    layout = graph.build_community_layout(n, edges, part, num_parts=6,
                                          pad_mode="bucketed")
    assert layout.num_parts == 6
    assert layout.sizes.tolist() == [3, 0, 3, 0, 1, 0]
    counts = layout.eff_row_counts()
    assert counts[1] == counts[3] == counts[5] == 0   # empty: zero rows
    assert counts[4] == 8                             # singleton: min bucket
    x = np.arange(n, dtype=np.float32)[:, None]
    np.testing.assert_array_equal(layout.unblockify(layout.blockify(x)), x)
    # pack/unpack agree on the same forced layout
    np.testing.assert_array_equal(layout.unpack(layout.pack(x)), x)


# ---------------------------------------------------------------------------
# pad accounting
# ---------------------------------------------------------------------------

def test_pad_stats_accounting(skewed_layout):
    _, layout = skewed_layout
    dims = [16, 8]
    bucketed = messages.pad_stats(layout.neighbor_mask, layout.sizes,
                                  layout.row_counts, layout.n_pad, dims)
    glob = messages.pad_stats(layout.neighbor_mask, layout.sizes, None,
                              layout.n_pad, dims)
    assert bucketed["pad_rows"] == layout.pad_rows
    assert bucketed["pad_bytes"] == layout.pad_rows * sum(dims) * 4
    assert bucketed["pad_bytes"] < glob["pad_bytes"]
    assert bucketed["pad_flops"] < glob["pad_flops"]
    # both schemes process at least the true rows; global processes n_pad
    assert bucketed["true_rows_total"] == glob["true_rows_total"] \
        == int(layout.sizes.sum())
    assert glob["padded_rows_total"] == layout.num_parts * layout.n_pad
    assert 0.0 <= bucketed["pad_flop_frac"] < glob["pad_flop_frac"] < 1.0
    with pytest.raises(ValueError):
        messages.pad_stats(layout.neighbor_mask, layout.sizes,
                           np.zeros(layout.num_parts), layout.n_pad, dims)


# ---------------------------------------------------------------------------
# row-exact exchange
# ---------------------------------------------------------------------------

def test_row_exact_wire_tracks_true_sizes(skewed_layout):
    """Row-exact scheduled wire == Σ true rows over wired messages (plus
    bounded round padding), strictly below the whole-block schedule."""
    _, layout = skewed_layout
    for n_shards in (2, 4, 8):
        whole = messages.build_neighbor_exchange(
            layout.neighbor_mask, n_shards, layout.n_pad)
        exact = messages.build_neighbor_exchange(
            layout.neighbor_mask, n_shards, layout.n_pad,
            sizes=layout.sizes)
        sw = messages.exchange_bytes(whole, [8])
        se = messages.exchange_bytes(exact, [8])
        assert se["wire_bytes"] < sw["wire_bytes"]
        assert se["p2p_needed_bytes"] < sw["p2p_needed_bytes"]
        # the true rows of every wired message are exact community sizes
        k = exact.lanes_per_shard
        expect = 0
        for dst in range(n_shards):
            for r in exact.needed_ids[dst]:
                if r // k != dst:
                    expect += int(layout.sizes[r])
        assert se["true_rows"] == expect
    with pytest.raises(ValueError):
        messages.build_neighbor_exchange(layout.neighbor_mask, 2,
                                         layout.n_pad,
                                         sizes=layout.sizes + layout.n_pad)


def test_row_exact_exchange_delivers_host_sim(skewed_layout):
    """Numpy simulation of exchange_neighbors over the row-exact plan:
    every shard ends with exactly the payload rows of its needed ids (pad
    rows zero), matching the lane-major slot map."""
    _, layout = skewed_layout
    m, n = layout.num_parts, layout.n_pad
    rng = np.random.default_rng(1)
    x = rng.normal(size=(m, n, 3)).astype(np.float32)
    for c in range(m):
        x[c, int(layout.sizes[c]):] = 0.0          # trainer invariant
    for n_shards in (2, 4):
        plan = messages.build_neighbor_exchange(
            layout.neighbor_mask, n_shards, n, sizes=layout.sizes)
        k = plan.lanes_per_shard
        for s in range(n_shards):
            x_flat = x[s * k:(s + 1) * k].reshape(k * n, -1)
            buf = np.zeros((plan.r_pad * n, 3), np.float32)
            own = (plan.own_slots[s][:, None] * n
                   + np.arange(n)[None, :]).reshape(-1)
            buf[own] = x_flat
            for rnd in plan.rounds:
                for src, dst in rnd.pairs:
                    if dst != s:
                        continue
                    payload = x[src * k:(src + 1) * k].reshape(
                        k * n, -1)[rnd.send_idx[src]]
                    keep = rnd.recv_slot[dst] < plan.r_pad * n
                    buf[rnd.recv_slot[dst][keep]] = payload[keep]
            buf = buf.reshape(plan.r_pad, n, 3)
            for slot, gid in enumerate(plan.needed_ids[s]):
                np.testing.assert_array_equal(buf[slot], x[gid])
            for slot in range(len(plan.needed_ids[s]), plan.r_pad):
                assert np.abs(buf[slot]).max() == 0.0


# ---------------------------------------------------------------------------
# trainer A/B: ragged vs global padding
# ---------------------------------------------------------------------------

def _skewed_trainer_case():
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=16, attach=1, seed=2, feat_dim=8,
        size_skew=0.8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    return g, part, cfg, admm


def test_trainer_pad_modes_bit_compatible_and_stats_drop():
    """pad_mode only changes what is processed/wired: global and bucketed
    trainers produce identical W/Z/U and Lagrangian, while the bucketed
    comm_stats record strictly less padding — on the axes whose consumer
    is actually engaged (row-exact p2p wire; guarded kernel with
    use_kernel)."""
    g, part, cfg, admm = _skewed_trainer_case()
    glob = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                               compressed=True, pad_mode="global",
                               use_kernel=True)
    buck = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                               compressed=True, pad_mode="bucketed",
                               use_kernel=True)
    assert glob.comm_stats["pad_mode"] == "global"
    assert buck.comm_stats["pad_mode"] == "bucketed"
    assert buck.comm_stats["pad_guards"] == {"kernel": True, "wire": True}
    assert buck.comm_stats["pad_bytes"] < glob.comm_stats["pad_bytes"]
    assert buck.comm_stats["pad_flops"] < glob.comm_stats["pad_flops"]
    # stats are gated on the consumer: without the guarded kernel the
    # einsum aggregation processes every n_pad row, so bucketed pad_flops
    # must NOT claim the skip; an allgather transport wires full-pad
    # payloads, so bucketed pad_bytes must not claim the wire win either
    nok = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                              compressed=True, pad_mode="bucketed")
    assert nok.comm_stats["pad_guards"] == {"kernel": False, "wire": True}
    assert nok.comm_stats["pad_flops"] == glob.comm_stats["pad_flops"]
    assert nok.comm_stats["pad_bytes"] == buck.comm_stats["pad_bytes"]
    nag = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                              compressed=True, pad_mode="bucketed",
                              transport="allgather")
    assert nag.comm_stats["pad_guards"]["wire"] is False
    assert nag.comm_stats["pad_bytes"] == glob.comm_stats["pad_bytes"]
    for _ in range(3):
        glob.step()
        buck.step()
    for za, zb in zip(glob.state.zs, buck.state.zs):
        np.testing.assert_allclose(np.asarray(za), np.asarray(zb),
                                   rtol=2e-4, atol=2e-5)
    for wa, wb in zip(glob.state.weights, buck.state.weights):
        np.testing.assert_allclose(np.asarray(wa), np.asarray(wb),
                                   rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(glob.state.u),
                               np.asarray(buck.state.u),
                               rtol=2e-4, atol=2e-5)
    lg = float(glob._lagrangian(glob.state))
    lb = float(buck._lagrangian(buck.state))
    assert lb == pytest.approx(lg, rel=1e-5)
    with pytest.raises(ValueError):
        ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            compressed=True, pad_mode="diagonal")


def test_trainer_kernel_interpret_with_ragged_counts():
    """The interpret-mode Pallas ELL kernel under ragged row counts matches
    the einsum path through a full ADMM step on a skewed layout."""
    from repro.kernels import ops as kops

    g, part, cfg, admm = _skewed_trainer_case()
    base = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                               compressed=True, pad_mode="bucketed")
    base.step()
    kops.repro_force_interpret(True)
    try:
        kern = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0,
                                   part=part, compressed=True,
                                   pad_mode="bucketed", use_kernel=True)
        kern.step()
    finally:
        kops.repro_force_interpret(False)
    for zb, zk in zip(base.state.zs, kern.state.zs):
        np.testing.assert_allclose(np.asarray(zb), np.asarray(zk),
                                   rtol=2e-4, atol=2e-5)
    for wb, wk in zip(base.state.weights, kern.state.weights):
        np.testing.assert_allclose(np.asarray(wb), np.asarray(wk),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# bf16 ELL block store
# ---------------------------------------------------------------------------

def test_adjacency_bf16_halves_blocks_and_stays_close():
    """CommunityData(adjacency_bf16=True): bf16 resident blocks (halved
    bytes, itemsize-aware accounting) with f32 accumulation — parity with
    the f32 store at loose tolerance over 3 iterations."""
    g, part, cfg, admm = _skewed_trainer_case()
    f32 = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                              compressed=True)
    b16 = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                              compressed=True, adjacency_bf16=True)
    assert b16.data.adjacency_bf16 and not f32.data.adjacency_bf16
    assert b16.data.ell_blocks.dtype == jnp.bfloat16
    # exactly the block plane halves; indices/mask stay full precision
    assert b16.data.ell_blocks.nbytes * 2 == f32.data.ell_blocks.nbytes
    assert b16.data.adjacency_nbytes < f32.data.adjacency_nbytes
    # the analytic accounting tracks the actual resident bytes
    assert b16.comm_stats["adjacency"]["ell_bytes"] == \
        b16.data.adjacency_nbytes
    assert b16.comm_stats["adjacency"]["block_itemsize"] == 2
    for _ in range(3):
        f32.step()
        b16.step()
    for zf, zb in zip(f32.state.zs, b16.state.zs):
        np.testing.assert_allclose(np.asarray(zf), np.asarray(zb),
                                   rtol=0.05, atol=0.05)
    for wf, wb in zip(f32.state.weights, b16.state.weights):
        np.testing.assert_allclose(np.asarray(wf), np.asarray(wb),
                                   rtol=0.05, atol=0.05)
    with pytest.raises(ValueError):
        ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            adjacency_bf16=True)      # dense + bf16 store


# ---------------------------------------------------------------------------
# 4-shard subprocess: ragged p2p trainer vs the serial trainer
# ---------------------------------------------------------------------------

_RAGGED_WORKER = r"""
import jax
import numpy as np
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.serial import SerialADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

N_SHARDS = 4
assert len(jax.devices()) >= N_SHARDS, jax.devices()
g, part = graph.synthetic_powerlaw_communities(
    num_parts=12, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
    size_skew=0.9)
sizes = np.bincount(part, minlength=12)
assert sizes.max() >= 2 * sizes.min()          # genuinely skewed
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh = make_mesh((N_SHARDS,), (AXIS,), devices=jax.devices()[:N_SHARDS])

serial = SerialADMMTrainer(cfg, admm, g, seed=0)
rag = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh, compressed=True, pad_mode="bucketed")
glo = ParallelADMMTrainer(cfg, admm, g, num_parts=12, seed=0, part=part,
                          mesh=mesh, compressed=True, pad_mode="global")
assert rag.transport == "p2p" and rag.comm_stats["pad_mode"] == "bucketed"
assert rag.comm_stats["wire_bytes"] < glo.comm_stats["wire_bytes"]
assert rag.comm_stats["pad_bytes"] < glo.comm_stats["pad_bytes"]
for _ in range(3):
    serial.step(); rag.step(); glo.step()

# ragged == global bit-compatible on the same mesh
for za, zb in zip(rag.state.zs, glo.state.zs):
    np.testing.assert_allclose(np.asarray(za), np.asarray(zb),
                               rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(np.asarray(rag.state.u), np.asarray(glo.state.u),
                           rtol=2e-4, atol=2e-5)
print("PAD_PARITY_OK")

# ragged p2p == the serial trainer (W/Z/U + Lagrangian)
for zs_, zp in zip(serial.state.zs, rag.state.zs):
    np.testing.assert_allclose(np.asarray(zs_),
                               rag.layout.unpack(np.asarray(zp)),
                               rtol=2e-3, atol=2e-4)
for ws, wp in zip(serial.state.weights, rag.state.weights):
    np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                               rtol=2e-3, atol=2e-4)
np.testing.assert_allclose(np.asarray(serial.state.u),
                           rag.layout.unpack(np.asarray(rag.state.u)),
                           rtol=2e-3, atol=2e-4)
lag_s = float(serial._lagr(serial.a_tilde, serial.z0, serial.labels,
                           serial.train_mask, serial.state))
lag_r = float(rag._lagrangian(rag.state))
assert abs(lag_s - lag_r) <= 1e-4 * max(1.0, abs(lag_s)), (lag_s, lag_r)
print("SERIAL_PARITY_OK")

# the ragged p2p step still compiles gather-free (analysis rule proof)
from repro import analysis
rep = analysis.analyze_trainer(rag, config="ragged-p2p")
assert analysis.no_findings(rep, rule="collective/no-allgather-under-p2p")
assert analysis.no_findings(rep, rule="collective/permute-schedule")
assert not rep.errors(), rep.summary()
print("HLO_OK")
"""


def test_ragged_p2p_matches_serial_on_4_shards():
    """The acceptance run: a 4-shard ragged (bucketed, row-exact p2p)
    trainer on a size-skewed graph matches the serial trainer's W/Z/U and
    Lagrangian after 3 iterations, wires strictly fewer bytes than the
    global-pad trainer, and compiles without an all-gather."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _RAGGED_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("PAD_PARITY_OK", "SERIAL_PARITY_OK", "HLO_OK"):
        assert tag in out.stdout, out.stdout
