"""repro.analysis unit tests: each rule must fire on a deliberately broken
program and stay silent on the blessed pattern.

The HLO-level rules are exercised on small canned HLO texts (no
compilation — these run in milliseconds); the jaxpr rule on traced
functions; the Pallas rules on hand-built and real kernel specs,
including the ISSUE's acceptance cases — an out-of-bounds index map, an
over-budget VMEM spec, and the estimate-vs-footprint parity bound.
"""
import numpy as np
import pytest

from repro import analysis
from repro.analysis.findings import Finding, Severity, Waiver, apply_waivers
from repro.analysis.rules.pallas import (VMEM_BUDGET_BYTES,
                                         check_kernel_bounds,
                                         check_kernel_vmem,
                                         check_tile_alignment)
from repro.analysis.rules.precision import check_jaxpr_precision
from repro.kernels.community_spmm import (BlockOperand, KernelSpec, ell_spec,
                                          spmm_spec)


def _hlo(body: str) -> str:
    return ("HloModule test\n\n"
            "ENTRY %main (p0: f32[8,8]) -> f32[8,8] {\n"
            + body + "\n}\n")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_all_families():
    rules = analysis.all_rules()
    fams = {r.family for r in rules}
    assert {"collective", "memory", "precision", "pallas"} <= fams
    assert len({r.id for r in rules}) == len(rules)
    assert all(r.doc for r in rules), "every rule carries a docstring"


def test_rules_skip_on_empty_context():
    rep = analysis.analyze_hlo("", expectations={})
    assert rep.findings == []
    assert len(rep.rules_run) == len(analysis.all_rules())


# ---------------------------------------------------------------------------
# collective rules
# ---------------------------------------------------------------------------


def test_no_allgather_fires_only_under_p2p():
    text = _hlo(
        "  %p0 = f32[8,8]{1,0} parameter(0)\n"
        "  ROOT %ag = f32[16,8]{1,0} all-gather(f32[8,8]{1,0} %p0), "
        "dimensions={0}")
    bad = analysis.analyze_hlo(text, expectations={"transport": "p2p"})
    assert bad.findings_for("collective/no-allgather-under-p2p")
    ok = analysis.analyze_hlo(text, expectations={"transport": "allgather"})
    assert not ok.findings_for("collective/no-allgather-under-p2p")


def test_permute_schedule_matches_host_plan():
    text = _hlo(
        "  %p0 = f32[8,8]{1,0} parameter(0)\n"
        "  ROOT %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %p0), "
        "source_target_pairs={{0,1},{1,0}}")
    ok = analysis.analyze_hlo(
        text, expectations={"round_pairs": [((0, 1), (1, 0))]})
    assert not ok.findings_for("collective/permute-schedule")
    # a round the host never scheduled, and a scheduled round that never
    # compiled, are both errors
    bad = analysis.analyze_hlo(
        text, expectations={"round_pairs": [((0, 1),), ((1, 0),)]})
    msgs = [f.message for f in bad.findings_for("collective/permute-schedule")]
    assert any("not in the host plan" in m for m in msgs)
    assert any("never compiled" in m for m in msgs)
    none = analysis.analyze_hlo(
        _hlo("  ROOT %p0 = f32[8,8]{1,0} parameter(0)"),
        expectations={"round_pairs": [((0, 1),)]})
    assert none.findings_for("collective/permute-schedule")


def test_allreduce_payload_budget():
    text = _hlo(
        "  %p0 = f32[8,8]{1,0} parameter(0)\n"
        "  ROOT %ar = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %p0), "
        "to_apply=%add")
    ok = analysis.analyze_hlo(text,
                              expectations={"allreduce_max_bytes": 4096})
    assert not ok.findings_for("collective/allreduce-payload")
    bad = analysis.analyze_hlo(text,
                               expectations={"allreduce_max_bytes": 16})
    assert bad.findings_for("collective/allreduce-payload")


# ---------------------------------------------------------------------------
# memory rules
# ---------------------------------------------------------------------------


def test_dense_adjacency_intermediate_is_flagged():
    exp = {"n_pad": 16, "lanes": 1, "max_deg": 2, "m_total": 4}
    # a computed (4, 16, 16) block stack: 4 blocks > lanes*max_deg = 2
    text = _hlo(
        "  %p0 = f32[4,16,16]{2,1,0} parameter(0)\n"
        "  ROOT %b = f32[4,16,16]{2,1,0} broadcast(f32[4,16,16]{2,1,0} %p0), "
        "dimensions={0,1,2}")
    bad = analysis.analyze_hlo(text, expectations=exp)
    hits = bad.findings_for("memory/no-dense-adjacency")
    assert len(hits) == 1 and hits[0].location == "b"
    # the parameter itself is within the full-M ELL store bound (4*2=8)
    assert not any(f.location == "p0" for f in hits)
    # the dense baseline waives the pattern wholesale
    ok = analysis.analyze_hlo(
        text, expectations=dict(exp, dense_adjacency_allowed=True))
    assert not ok.findings_for("memory/no-dense-adjacency")


def test_hbm_budget_and_host_transfer():
    text = _hlo(
        "  %p0 = f32[1024,1024]{1,0} parameter(0)\n"
        "  ROOT %e = f32[1024,1024]{1,0} exponential(f32[1024,1024]{1,0} "
        "%p0)")
    bad = analysis.analyze_hlo(
        text, expectations={"hbm_intermediate_budget": 1 << 20})
    assert bad.findings_for("memory/hbm-intermediate-budget")
    ok = analysis.analyze_hlo(
        text, expectations={"hbm_intermediate_budget": 1 << 23})
    assert not ok.findings_for("memory/hbm-intermediate-budget")

    outfeed = _hlo(
        "  %p0 = f32[8,8]{1,0} parameter(0)\n"
        "  ROOT %o = token[] outfeed(f32[8,8]{1,0} %p0)")
    assert analysis.analyze_hlo(outfeed).findings_for(
        "memory/host-transfer")


def test_donated_inputs_rule():
    exp = {"expect_donated": (".zs", ".u"),
           "args_donated": {"[0].zs[0]": True, "[0].zs[1]": False,
                            "[0].u": True, "[0].taus[0]": False}}
    rep = analysis.analyze_hlo("", expectations=exp)
    hits = rep.findings_for("memory/donated-inputs")
    assert len(hits) == 1 and ".zs" in hits[0].message
    clean = analysis.analyze_hlo("", expectations={
        "expect_donated": (".zs",), "args_donated": {"[0].zs[0]": True}})
    assert not clean.findings_for("memory/donated-inputs")
    # a stale expectation (no matching arg at all) is a warning
    stale = analysis.analyze_hlo("", expectations={
        "expect_donated": (".zq",), "args_donated": {"[0].zs[0]": True}})
    hits = stale.findings_for("memory/donated-inputs")
    assert hits and hits[0].severity == Severity.WARNING


# ---------------------------------------------------------------------------
# precision rules
# ---------------------------------------------------------------------------


def test_bf16_dot_without_f32_accumulate_is_flagged():
    bad = _hlo(
        "  %a = bf16[8,8]{1,0} parameter(0)\n"
        "  %b = bf16[8,8]{1,0} parameter(1)\n"
        "  ROOT %d = bf16[8,8]{1,0} dot(bf16[8,8]{1,0} %a, bf16[8,8]{1,0} "
        "%b), lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    rep = analysis.analyze_hlo(bad)
    assert rep.findings_for("precision/bf16-dot-accumulate")
    # the blessed pattern: f32 result dot over bf16 operands
    good = _hlo(
        "  %a = bf16[8,8]{1,0} parameter(0)\n"
        "  %b = bf16[8,8]{1,0} parameter(1)\n"
        "  ROOT %d = f32[8,8]{1,0} dot(bf16[8,8]{1,0} %a, bf16[8,8]{1,0} "
        "%b), lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    assert not analysis.analyze_hlo(good).findings_for(
        "precision/bf16-dot-accumulate")


def test_f64_leak_is_flagged_unless_allowed():
    text = _hlo("  ROOT %c = f64[4]{0} constant({1, 2, 3, 4})")
    assert analysis.analyze_hlo(text).findings_for("precision/no-f64")
    ok = analysis.analyze_hlo(text, expectations={"allow_f64": True})
    assert not ok.findings_for("precision/no-f64")


def test_jaxpr_dataflow_catches_missing_f32_accumulate():
    import jax
    import jax.numpy as jnp

    def bad(a, b):
        return jax.lax.dot(a, b)                    # bf16 accumulate

    def good(a, b):
        return jax.lax.dot(a, b,
                           preferred_element_type=jnp.float32)

    a = jnp.zeros((8, 8), jnp.bfloat16)
    findings = check_jaxpr_precision(jax.make_jaxpr(bad)(a, a))
    assert any(f.rule == "precision/jaxpr-dataflow"
               and f.severity == Severity.ERROR for f in findings)
    assert not check_jaxpr_precision(jax.make_jaxpr(good)(a, a))


# ---------------------------------------------------------------------------
# pallas kernel rules (ISSUE acceptance: OOB index map, over-budget VMEM,
# estimate-vs-footprint parity)
# ---------------------------------------------------------------------------


def _toy_spec(index_map, *, grid=(4, 2), blocks=(64, 128),
              array=(256, 256)):
    return KernelSpec(
        name="toy", grid=grid,
        operands=(BlockOperand("x", array, blocks, index_map),),
        scratch_bytes=0)


def test_oob_index_map_is_flagged():
    # block row 4 of 4 — one past the end on the last grid step
    bad = _toy_spec(lambda i, j: (i + 1, j))
    findings = check_kernel_bounds(bad)
    assert findings and findings[0].rule == "pallas/index-bounds"
    assert "out of range" in findings[0].message
    ok = _toy_spec(lambda i, j: (i, j))
    assert not check_kernel_bounds(ok)


def test_oob_scalar_prefetch_indices_are_flagged():
    # 6 communities but an ELL index pointing at community 9
    spec = ell_spec(k=2, max_deg=2, n_pad=16, c=16, m_total=6)
    good = {"ell_indices": np.array([[0, 5], [1, 2]], np.int32),
            "ell_mask": np.ones((2, 2), np.int32),
            "row_counts": np.full((2,), 16, np.int32),
            "nbr_counts": np.full((2, 2), 16, np.int32)}
    assert not check_kernel_bounds(spec, good)
    bad = dict(good, ell_indices=np.array([[0, 9], [1, 2]], np.int32))
    findings = check_kernel_bounds(spec, bad)
    assert findings and findings[0].rule == "pallas/index-bounds"
    assert "out of range" in findings[0].message
    assert findings[0].details["index"] == 9


def test_over_budget_vmem_spec_is_flagged():
    # 2 MiB blocks, double-buffered -> 4 MiB > a 1 MiB budget
    big = _toy_spec(lambda i, j: (i, j), blocks=(512, 1024),
                    array=(2048, 2048), grid=(4, 2))
    findings = check_kernel_vmem(big, budget=1 << 20)
    assert findings and findings[0].rule == "pallas/vmem-budget"
    assert not check_kernel_vmem(big)   # default 16 MiB budget fits


def test_ell_vmem_estimate_within_2x_of_spec_footprint():
    """Parity: the linter's VMEM estimate stays within [1x, 2x] of the
    single-buffered footprint derived from the same spec (the factor is
    the pipeline double-buffering)."""
    for k, max_deg, n_pad, c, m in [(2, 2, 256, 256, 8), (4, 3, 512, 64, 16),
                                    (1, 1, 128, 128, 4)]:
        spec = ell_spec(k, max_deg, n_pad, c, m)
        footprint = (sum(op.block_bytes() for op in spec.operands)
                     + spec.scratch_bytes)
        est = spec.vmem_bytes()
        assert footprint <= est <= 2 * footprint, (spec.name, est, footprint)
        assert est <= VMEM_BUDGET_BYTES, "benchmark tiles must fit VMEM"


def test_real_kernel_specs_pass_all_pallas_rules():
    """The shipped kernels' own specs are clean under every Pallas rule —
    the same check analyze_trainer runs on benchmark configs."""
    d = spmm_spec(m=8, n_pad=256, c=256)
    assert not check_kernel_bounds(d)
    assert not check_kernel_vmem(d)
    assert not check_tile_alignment(d)
    e = ell_spec(k=2, max_deg=3, n_pad=256, c=256, m_total=8)
    scalars = {"ell_indices": np.zeros((2, 3), np.int32),
               "ell_mask": np.ones((2, 3), np.int32),
               "row_counts": np.full((2,), 256, np.int32),
               "nbr_counts": np.full((2, 3), 256, np.int32)}
    assert not check_kernel_bounds(e, scalars)
    assert not check_kernel_vmem(e)
    assert not check_tile_alignment(e)


def test_tile_alignment_warns_on_ragged_blocks():
    # 100 is neither 128-aligned nor the full dim
    bad = _toy_spec(lambda i, j: (0, 0), blocks=(64, 100),
                    array=(256, 400), grid=(1, 1))
    findings = check_tile_alignment(bad)
    assert findings and findings[0].severity == Severity.WARNING


# ---------------------------------------------------------------------------
# findings / report plumbing
# ---------------------------------------------------------------------------


def test_waiver_mutes_matching_configs_only():
    f = Finding("memory/no-dense-adjacency", Severity.ERROR, "boom")
    w = Waiver("memory/no-dense-adjacency", "dense baseline",
               when={"compressed": False})
    kept, waived = apply_waivers([f], {"compressed": False}, [w])
    assert not kept and len(waived) == 1
    kept, waived = apply_waivers([f], {"compressed": True}, [w])
    assert len(kept) == 1 and not waived


def test_no_findings_severity_threshold():
    warn = Finding("precision/bf16-reduce", Severity.WARNING, "w")
    err = Finding("precision/no-f64", Severity.ERROR, "e")
    assert analysis.no_findings([warn], min_severity=Severity.ERROR)
    assert not analysis.no_findings([warn])
    assert not analysis.no_findings([warn, err], rule="precision/no-f64",
                                    min_severity=Severity.ERROR)
    assert analysis.no_findings([err], rule="precision/bf16-reduce")


def test_report_json_round_trip():
    import json

    rep = analysis.analyze_hlo(
        _hlo("  ROOT %c = f64[4]{0} constant({1, 2, 3, 4})"),
        config="rt", expectations={"n_pad": 8})
    with pytest.raises(AssertionError):
        rep.assert_no_findings()
    blob = json.loads(rep.to_json())
    assert blob["config"] == "rt"
    assert blob["findings"][0]["rule"] == "precision/no-f64"
    assert blob["findings"][0]["severity"] == "error"
    assert blob["expectations"]["n_pad"] == 8
