"""CI smoke for the benchmark JSON emitters: --quick runs must produce
machine-readable BENCH_*.json payloads with the (mode, M, bytes,
per-epoch seconds) fields the perf trajectory tracking consumes."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)   # benchmarks/ namespace package

from benchmarks import check_bench  # noqa: E402


def _run_bench(script: str, out_path: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", script),
         "--quick", "--out", out_path],
        capture_output=True, text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    with open(out_path) as fh:
        return json.load(fh)


def test_block_sparsity_quick_json(tmp_path):
    payload = _run_bench("block_sparsity.py",
                         str(tmp_path / "BENCH_block_sparsity.json"))
    assert payload["quick"] is True
    assert payload["agg_sweep"] and payload["trainer_sweep"]
    # check_bench enforces the wire ≤ needed ≤ full chain per row
    check_bench.check_block_sparsity(payload)
    modes = {r["mode"] for r in payload["trainer_sweep"]}
    assert modes == {"dense", "compressed"}
    for r in payload["trainer_sweep"]:
        assert {"mode", "M", "adjacency_bytes", "per_epoch_s"} <= set(r)
        assert r["adjacency_bytes"] > 0 and r["per_epoch_s"] > 0
    # compressed adjacency tracks nnz blocks: at small M a near-dense block
    # graph only pays the tiny index/mask overhead, and at the largest M of
    # the sweep the compressed form must already be strictly smaller
    by_m = {}
    for r in payload["trainer_sweep"]:
        by_m.setdefault(r["M"], {})[r["mode"]] = r["adjacency_bytes"]
    for m, d in by_m.items():
        assert d["compressed"] <= d["dense"] * 1.01 + 4096, (m, d)
    top = by_m[max(by_m)]
    assert top["compressed"] < top["dense"], top


@pytest.mark.slow
def test_speedup_quick_json(tmp_path):
    payload = _run_bench("speedup.py", str(tmp_path / "BENCH_speedup.json"))
    assert payload["quick"] is True
    check_bench.check_speedup(payload)
    modes = {r["mode"] for r in payload["rows"]}
    assert modes == {"parallel", "compressed", "p2p", "p2p_ml"}
    # the p2p transport's wire-byte win at M=32 (acceptance criterion)
    assert payload["m32_wire"]["wire_bytes"] < payload["m32_wire"]["full_bytes"]
    # the multilevel partitioner's cut win at M=32 (acceptance criterion):
    # strictly fewer cut edges, no worse ELL fan-in, no more wire
    mp = payload["m32_partition"]["methods"]
    assert mp["multilevel"]["edge_cut"] < mp["bfs_kl"]["edge_cut"]
    assert mp["multilevel"]["max_deg"] <= mp["bfs_kl"]["max_deg"]
    assert mp["multilevel"]["wire_bytes"] <= mp["bfs_kl"]["wire_bytes"]
    for r in payload["rows"]:
        assert {"mode", "dataset", "adjacency_bytes",
                "parallel_per_epoch_s", "serial_per_epoch_s"} <= set(r)
        assert r["parallel_per_epoch_s"] > 0
    comp = next(r for r in payload["rows"] if r["mode"] == "compressed")
    par = next(r for r in payload["rows"] if r["mode"] == "parallel")
    # M=3 on an SBM graph is block-dense, so ELL only adds its small
    # index/mask overhead here; the compression win is block_sparsity.py's
    # power-law M-sweep
    assert comp["adjacency_bytes"] <= par["adjacency_bytes"] * 1.01
