"""CommunityServer engine tests: correctness vs the dense forward,
cache determinism/parity, incremental invalidation, batching shapes, and
the compiled hit path's zero-collective guarantee."""
import jax
import numpy as np
import pytest

from repro.core import gcn, graph
from repro.serve import CommunityServer, ServeConfig

M = 8


def _build(config: "ServeConfig | None" = None, seed: int = 0):
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=M, nodes_per_part=12, attach=1, seed=seed, feat_dim=8,
        size_skew=0.8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed", num_parts=M)
    ws = gcn.init_weights(cfg, jax.random.key(seed))
    srv = CommunityServer(cfg, layout, ws, g.features, config)
    return g, cfg, ws, srv


@pytest.fixture(scope="module")
def served():
    return _build()


def test_serve_matches_dense_forward(served):
    g, cfg, ws, srv = served
    a = graph.normalized_adjacency(g.num_nodes, g.edges)
    want = np.asarray(gcn.forward(cfg, a, g.features, ws)[-1])
    got = srv.serve(np.arange(g.num_nodes))
    # per-community self+halo split reassociates the dense contraction
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=1e-4)


def test_hit_after_miss_is_bitwise(served):
    g, _, _, srv = served
    rng = np.random.default_rng(0)
    ids = rng.integers(0, g.num_nodes, size=48)
    first = srv.serve(ids)          # fills the cache for these communities
    h0 = srv.request_hits
    second = srv.serve(ids)         # pure hit path
    assert srv.request_hits - h0 == len(ids)
    np.testing.assert_array_equal(first, second)


def test_request_order_preserved(served):
    g, _, _, srv = served
    ids = np.array([g.num_nodes - 1, 0, 5, 0, 17, 3])
    out = srv.serve(ids)
    singles = np.concatenate([srv.serve(np.array([i])) for i in ids])
    np.testing.assert_array_equal(out, singles)


def test_cache_disabled_is_bitwise_parity():
    g, _, _, on = _build(ServeConfig(cache_enabled=True))
    _, _, _, off = _build(ServeConfig(cache_enabled=False))
    ids = np.arange(g.num_nodes)
    a = on.serve(ids)
    b = off.serve(ids)
    np.testing.assert_array_equal(a, b)
    # disabled really caches nothing and recomputes every batch
    assert len(off.embed_cache) == 0 and off.request_hits == 0
    assert off.block_computes > on.block_computes


def test_fused_cold_path_matches(served):
    g, _, _, srv = served
    _, _, _, fused = _build(ServeConfig(fused=True, cache_enabled=False))
    ids = np.arange(g.num_nodes)
    np.testing.assert_allclose(fused.serve(ids), srv.serve(ids),
                               atol=5e-5, rtol=1e-4)


def test_invalidation_matches_dependency_tables():
    g, cfg, ws, srv = _build()
    srv.serve(np.arange(g.num_nodes))       # warm every cache line
    n_l = cfg.num_layers
    assert len(srv.embed_cache) > 0

    node = 0
    feats = np.asarray(g.features)[[node]] + 1.0
    rep = srv.update_features([node], feats)

    # the dirty sets are the read closure of node 0's community
    seeds = np.array([srv.node_comm[node]])
    closure = graph.read_closure(srv.neighbor_mask, seeds, hops=n_l)
    for hop, want in enumerate(closure):
        np.testing.assert_array_equal(rep["dirty"][hop], want)

    nbr_cross = srv.neighbor_mask & ~np.eye(M, dtype=bool)
    for layer in range(1, n_l + 1):
        want_embed = {(int(m), layer) for m in closure[layer]}
        got_embed = {k for k in rep["embed"] if k[1] == layer}
        assert got_embed == want_embed
        want_halo = {(int(m), layer) for m in np.flatnonzero(
            nbr_cross[:, closure[layer - 1]].any(axis=1))}
        got_halo = {k for k in rep["halo"] if k[1] == layer}
        assert got_halo == want_halo

    # communities outside the hop-1 closure keep their layer-1 lines
    clean = set(range(M)) - set(int(m) for m in closure[1])
    assert clean, "test graph too dense to observe surviving cache lines"
    for m in clean:
        assert (m, 1) in srv.embed_cache


def test_post_update_serving_matches_fresh_engine():
    g, cfg, ws, srv = _build()
    ids = np.arange(g.num_nodes)
    srv.serve(ids)
    rng = np.random.default_rng(1)
    touched = np.array([2, 40, 41])
    feats = rng.normal(size=(3, cfg.layer_dims[0])).astype(np.float32)
    srv.update_features(touched, feats)

    new_features = np.asarray(g.features).copy()
    new_features[touched] = feats
    fresh = CommunityServer(cfg, srv.layout, ws, new_features)
    np.testing.assert_array_equal(srv.serve(ids), fresh.serve(ids))


def test_update_features_validates_shape(served):
    g, cfg, _, srv = served
    with pytest.raises(ValueError, match="feats shape"):
        srv.update_features([0], np.zeros((2, cfg.layer_dims[0]),
                                          np.float32))


def test_batcher_buckets_on_pad_ladder(served):
    g, _, _, srv = served
    rng = np.random.default_rng(2)
    ids = rng.integers(0, g.num_nodes, size=100)
    batches = srv.batcher.coalesce(ids)
    ladder = set(srv.batcher.ladder)
    seen = np.concatenate([b.positions for b in batches])
    assert sorted(seen) == list(range(len(ids)))
    for b in batches:
        assert b.bucket in ladder and b.bucket >= b.count
        np.testing.assert_array_equal(srv.node_comm[ids[b.positions]],
                                      b.comm)
        np.testing.assert_array_equal(b.rows[:b.count],
                                      srv.node_row[ids[b.positions]])
        np.testing.assert_array_equal(b.rows[b.count:], 0)


def test_hit_path_compiles_collective_free(served):
    from repro import analysis
    from repro.analysis import hlo as hlo_mod

    _, _, _, srv = served
    text = srv.hit_path_lowered(bucket=64).compile().as_text()
    census = hlo_mod.hlo_census(text)
    assert sum(v["count"] for v in census.collectives.values()) == 0
    rep = analysis.analyze_hlo(text, expectations={
        "expect_zero_collectives": True,
        "full_graph_rows": int(srv.dl.plane_rows),
    }, config="serve_hit")
    assert not rep.errors()


def test_stats_shape(served):
    _, _, _, srv = served
    srv.serve(np.array([0, 1, 2]))
    s = srv.stats()
    assert {"requests", "block_computes", "halo_computes", "embed_cache",
            "halo_cache"} <= set(s)
    assert s["requests"]["total"] >= 3
