"""Deep (3-layer) GCN ADMM: exercises the middle-layer ψ subproblem
(eq. 5, next layer hidden) in both serial and parallel trainers, which the
paper's 2-layer experiments never touch."""
import numpy as np
import pytest

from repro.core import gcn, graph
from repro.core.serial import SerialADMMTrainer
from repro.core.subproblems import ADMMConfig


@pytest.fixture(scope="module")
def setup():
    g = graph.synthetic_sbm("amazon_photo_mini", seed=2)
    cfg = gcn.GCNConfig(layer_dims=(745, 64, 32, 8))   # L = 3
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    return g, cfg, admm


@pytest.mark.slow
def test_serial_three_layer_learns(setup):
    g, cfg, admm = setup
    tr = SerialADMMTrainer(cfg, admm, g, seed=0)
    log = tr.train(20)
    assert log.train_acc[-1] > 0.5, log.train_acc
    assert np.isfinite(log.lagrangian).all()


def test_parallel_three_layer_matches_w_update(setup):
    """First-iteration W updates agree serial vs parallel for L=3 (the
    global W objective is identical in both)."""
    from repro.core.parallel import ParallelADMMTrainer
    g, cfg, admm = setup
    s = SerialADMMTrainer(cfg, admm, g, seed=0)
    p = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0)
    s.step()
    p.step()
    for layer, (ws, wp) in enumerate(zip(s.state.weights, p.state.weights)):
        np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                                   rtol=2e-4, atol=2e-6,
                                   err_msg=f"W_{layer + 1}")


@pytest.mark.slow
def test_parallel_three_layer_converges(setup):
    from repro.core.parallel import ParallelADMMTrainer
    g, cfg, admm = setup
    p = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0)
    log = p.train(20)
    assert log.train_acc[-1] > 0.5, log.train_acc
