"""Block-sparsity end-to-end: masked aggregation (einsum / ref oracle /
interpret-mode Pallas), the block-compressed (CSR-of-blocks / ELL) layout,
and the neighbour-aware parallel trainer agreeing with the dense path.

These run without hypothesis; test_property.py has generative versions.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph, messages
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.community_spmm import community_spmm as pallas_spmm


@pytest.fixture(scope="module")
def sparse_layout():
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=6, nodes_per_part=24, attach=1, seed=0, feat_dim=12)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True)
    return g, layout


def test_powerlaw_layout_is_block_sparse(sparse_layout):
    _, layout = sparse_layout
    m = layout.num_parts
    nbr = np.asarray(layout.neighbor_mask)
    assert nbr.diagonal().all()
    assert nbr.sum() < m * m, "power-law community graph must have absent blocks"
    # absent blocks are exactly zero in the dense layout
    absent = layout.a_blocks[~nbr]
    assert absent.size and np.abs(absent).max() == 0.0


def test_masked_spmm_all_paths_agree(sparse_layout):
    g, layout = sparse_layout
    rng = np.random.default_rng(0)
    c = 8
    z = jnp.asarray(layout.pack(
        rng.normal(size=(g.num_nodes, c)).astype(np.float32)))
    a = jnp.asarray(layout.a_blocks)
    nbr = jnp.asarray(layout.neighbor_mask)
    dense = jnp.einsum("mrip,rpc->mic", a, z)

    for me in range(layout.num_parts):
        oracle = ref.community_spmm_ref(a[me], z, nbr[me])
        np.testing.assert_allclose(np.asarray(oracle), np.asarray(dense[me]),
                                   rtol=1e-4, atol=1e-4)
        pallas = pallas_spmm(a[me], z, nbr[me], interpret=True)
        np.testing.assert_allclose(np.asarray(pallas), np.asarray(dense[me]),
                                   rtol=1e-4, atol=1e-4)

    # lane-batched dispatch with per-lane neighbour rows (the trainer path)
    lanes = kops.community_spmm(a, z, nbr)
    np.testing.assert_allclose(np.asarray(lanes), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_block_csr_roundtrip_and_ell_spmm(sparse_layout):
    g, layout = sparse_layout
    csr = layout.compress()
    assert csr is layout.block_csr          # cached when compressed=True
    assert csr.nnz == layout.nnz_blocks < layout.num_parts ** 2
    np.testing.assert_array_equal(csr.to_dense(), layout.a_blocks)

    rng = np.random.default_rng(1)
    x = rng.normal(size=(g.num_nodes, 5)).astype(np.float32)
    z = layout.pack(x)
    dense = np.einsum("mrip,rpc->mic", layout.a_blocks, z)
    np.testing.assert_allclose(csr.spmm(z), dense, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(layout.unpack(z), x, rtol=0, atol=0)

    zj = jnp.asarray(z)
    ell = kops.community_spmm_ell(jnp.asarray(csr.ell_blocks),
                                  jnp.asarray(csr.ell_indices),
                                  jnp.asarray(csr.ell_mask), zj)
    np.testing.assert_allclose(np.asarray(ell), dense, rtol=1e-4, atol=1e-4)
    oracle = ref.community_spmm_ell_ref(jnp.asarray(csr.ell_blocks),
                                        jnp.asarray(csr.ell_indices),
                                        jnp.asarray(csr.ell_mask), zj)
    np.testing.assert_allclose(np.asarray(oracle), dense,
                               rtol=1e-4, atol=1e-4)

    # compression is where the memory drops: nnz blocks vs M² blocks
    assert csr.blocks.nbytes < layout.a_blocks.nbytes


def test_gather_bytes_accounting(sparse_layout):
    _, layout = sparse_layout
    stats = messages.gather_bytes(layout.neighbor_mask, layout.n_pad, [16, 8])
    assert stats["needed_bytes"] < stats["full_bytes"]
    assert stats["nnz_blocks"] == layout.nnz_blocks
    assert 0.0 < stats["savings_ratio"] < 1.0
    # exact: needed/full == nnz/M²
    ratio = stats["needed_bytes"] / stats["full_bytes"]
    assert ratio == pytest.approx(layout.nnz_blocks / layout.num_parts ** 2)


def test_trainer_kernel_path_carries_mask():
    """use_kernel=True routes rowagg through kops.community_spmm with the
    per-lane neighbour rows (no mask=None call sites) — one ADMM step must
    match the masked-einsum path, both via the CPU ref dispatch and the
    interpret-mode Pallas kernel body."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=3, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    base = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0, part=part)
    base.step()

    for interpret in (False, True):
        kops.repro_force_interpret(interpret)
        try:
            kern = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0,
                                       part=part, use_kernel=True)
            kern.step()
        finally:
            kops.repro_force_interpret(False)
        for zb, zk in zip(base.state.zs, kern.state.zs):
            np.testing.assert_allclose(np.asarray(zb), np.asarray(zk),
                                       rtol=2e-4, atol=2e-5)
        for wb, wk in zip(base.state.weights, kern.state.weights):
            np.testing.assert_allclose(np.asarray(wb), np.asarray(wk),
                                       rtol=2e-4, atol=2e-5)


def test_compressed_trainer_no_dense_blocks_and_parity():
    """compressed=True must hold NO dense (M, M, n_pad, n_pad) tensor —
    only the sharded ELL rows — and produce allclose states with the dense
    trainer after 3 ADMM iterations (same seeds)."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    dense = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part)
    comp = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                               compressed=True)
    assert comp.data.a_blocks is None
    assert comp.data.compressed and not dense.data.compressed
    csr = comp.layout.block_csr
    assert comp.data.ell_blocks.shape == (4, csr.max_deg,
                                          comp.layout.n_pad,
                                          comp.layout.n_pad)
    # compressed representation is strictly smaller than the dense tensor,
    # and the host-side (BlockCSR), device-side (CommunityData) and
    # analytic (messages.adjacency_bytes) accountings all agree
    assert comp.data.adjacency_nbytes < dense.data.adjacency_nbytes
    assert csr.ell_nbytes == comp.data.adjacency_nbytes
    # and the recorded accounting matches what is actually resident
    adj = comp.comm_stats["adjacency"]
    assert adj["resident_bytes"] == comp.data.adjacency_nbytes
    assert adj["ell_bytes"] == comp.data.adjacency_nbytes
    assert dense.comm_stats["adjacency"]["resident_bytes"] == \
        dense.data.adjacency_nbytes == adj["dense_bytes"]

    for _ in range(3):
        dense.step()
        comp.step()
    for zd, zc in zip(dense.state.zs, comp.state.zs):
        np.testing.assert_allclose(np.asarray(zd), np.asarray(zc),
                                   rtol=2e-4, atol=2e-5)
    for wd, wc in zip(dense.state.weights, comp.state.weights):
        np.testing.assert_allclose(np.asarray(wd), np.asarray(wc),
                                   rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dense.state.u),
                               np.asarray(comp.state.u),
                               rtol=2e-4, atol=2e-5)


def test_compressed_trainer_kernel_path():
    """use_kernel=True in compressed mode routes aggregation through the
    Pallas ELL kernel (CPU ref dispatch and interpret-mode body) and must
    match the einsum path."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=3, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    base = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0, part=part,
                               compressed=True)
    base.step()
    for interpret in (False, True):
        kops.repro_force_interpret(interpret)
        try:
            kern = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0,
                                       part=part, compressed=True,
                                       use_kernel=True)
            kern.step()
        finally:
            kops.repro_force_interpret(False)
        for zb, zk in zip(base.state.zs, kern.state.zs):
            np.testing.assert_allclose(np.asarray(zb), np.asarray(zk),
                                       rtol=2e-4, atol=2e-5)
        for wb, wk in zip(base.state.weights, kern.state.weights):
            np.testing.assert_allclose(np.asarray(wb), np.asarray(wk),
                                       rtol=2e-4, atol=2e-5)


_MULTISHARD_WORKER = r"""
import jax
import numpy as np
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.serial import SerialADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

assert len(jax.devices()) >= 2, jax.devices()
g, part = graph.synthetic_powerlaw_communities(
    num_parts=4, nodes_per_part=16, attach=1, seed=3, feat_dim=8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh2 = make_mesh((2,), (AXIS,), devices=jax.devices()[:2])
mesh1 = make_mesh((1,), (AXIS,), devices=jax.devices()[:1])

# dense vs compressed on a 2-shard mesh (k=2 lanes per shard)
dense2 = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                             mesh=mesh2)
comp2 = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            mesh=mesh2, compressed=True)
assert comp2.data.a_blocks is None
# shard-count invariance: same M on a 1-shard mesh
comp1 = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part,
                            mesh=mesh1, compressed=True)
for _ in range(3):
    dense2.step(); comp2.step(); comp1.step()
for za, zb, zc in zip(dense2.state.zs, comp2.state.zs, comp1.state.zs):
    np.testing.assert_allclose(np.asarray(za), np.asarray(zb),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(zb), np.asarray(zc),
                               rtol=2e-4, atol=2e-5)
for wa, wb, wc in zip(dense2.state.weights, comp2.state.weights,
                      comp1.state.weights):
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wb),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(wb), np.asarray(wc),
                               rtol=2e-4, atol=2e-5)

# serial vs parallel (M=1): identical subproblems, one agent
s = SerialADMMTrainer(cfg, admm, g, seed=0)
p = ParallelADMMTrainer(cfg, admm, g, num_parts=1, seed=0, compressed=True)
for _ in range(3):
    s.step(); p.step()
for ws, wp in zip(s.state.weights, p.state.weights):
    np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                               rtol=2e-4, atol=2e-6)
np.testing.assert_allclose(np.asarray(s.state.zs[-1]),
                           p.layout.unpack(np.asarray(p.state.zs[-1])),
                           rtol=2e-3, atol=2e-4)
print("PARITY_OK")
"""


def test_parity_on_multi_shard_mesh():
    """Serial-vs-parallel and dense-vs-compressed parity on a real 2-shard
    host mesh (subprocess: XLA locks the device count at first init)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _MULTISHARD_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PARITY_OK" in out.stdout


def test_parallel_lagrangian_matches_global():
    """TrainLog.lagrangian must be the true augmented Lagrangian: the packed
    per-epoch value equals subproblems.lagrangian_value on unpacked state."""
    import jax.numpy as jnp

    from repro.core import gcn, subproblems
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig, ADMMState

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=3, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    p = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0, part=part,
                            compressed=True)
    log = p.train(2)
    lay = p.layout
    zs = tuple(jnp.asarray(lay.unpack(np.asarray(z))) for z in p.state.zs)
    u = jnp.asarray(lay.unpack(np.asarray(p.state.u)))
    st = ADMMState(p.state.weights, zs, u, p.state.taus, p.state.thetas)
    a = jnp.asarray(graph.normalized_adjacency(g.num_nodes, g.edges))
    ref_val = subproblems.lagrangian_value(
        cfg, admm, a, jnp.asarray(g.features), jnp.asarray(g.labels),
        jnp.asarray(g.train_mask, jnp.float32), st)
    assert log.lagrangian[-1] == pytest.approx(float(ref_val), rel=1e-4)
    assert log.lagrangian[-1] != 0.0


@pytest.mark.slow
def test_parallel_trainer_masked_matches_dense():
    """The neighbour-masked trainer reaches the same accuracy as a forced
    dense-mask run on a block-sparse community graph (absent blocks are
    zero, so masking must be loss-free) and records the byte savings."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=24, attach=1, seed=1, feat_dim=16)
    cfg = gcn.GCNConfig(layer_dims=(16, 16, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    masked = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part)
    assert np.asarray(masked.layout.neighbor_mask).sum() < 16
    assert masked.comm_stats["needed_bytes"] < masked.comm_stats["full_bytes"]

    dense = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part)
    dense.data = dataclasses.replace(
        dense.data, neighbor_mask=jnp.ones_like(dense.data.neighbor_mask))

    mlog = masked.train(6)
    dlog = dense.train(6)
    assert np.isfinite(mlog.residual).all()
    assert abs(mlog.test_acc[-1] - dlog.test_acc[-1]) <= 0.05
