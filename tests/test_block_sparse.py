"""Block-sparsity end-to-end: masked aggregation (einsum / ref oracle /
interpret-mode Pallas), the block-compressed (CSR-of-blocks / ELL) layout,
and the neighbour-aware parallel trainer agreeing with the dense path.

These run without hypothesis; test_property.py has generative versions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph, messages
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.community_spmm import community_spmm as pallas_spmm


@pytest.fixture(scope="module")
def sparse_layout():
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=6, nodes_per_part=24, attach=1, seed=0, feat_dim=12)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True)
    return g, layout


def test_powerlaw_layout_is_block_sparse(sparse_layout):
    _, layout = sparse_layout
    m = layout.num_parts
    nbr = np.asarray(layout.neighbor_mask)
    assert nbr.diagonal().all()
    assert nbr.sum() < m * m, "power-law community graph must have absent blocks"
    # absent blocks are exactly zero in the dense layout
    absent = layout.a_blocks[~nbr]
    assert absent.size and np.abs(absent).max() == 0.0


def test_masked_spmm_all_paths_agree(sparse_layout):
    g, layout = sparse_layout
    rng = np.random.default_rng(0)
    c = 8
    z = jnp.asarray(layout.pack(
        rng.normal(size=(g.num_nodes, c)).astype(np.float32)))
    a = jnp.asarray(layout.a_blocks)
    nbr = jnp.asarray(layout.neighbor_mask)
    dense = jnp.einsum("mrip,rpc->mic", a, z)

    for me in range(layout.num_parts):
        oracle = ref.community_spmm_ref(a[me], z, nbr[me])
        np.testing.assert_allclose(np.asarray(oracle), np.asarray(dense[me]),
                                   rtol=1e-4, atol=1e-4)
        pallas = pallas_spmm(a[me], z, nbr[me], interpret=True)
        np.testing.assert_allclose(np.asarray(pallas), np.asarray(dense[me]),
                                   rtol=1e-4, atol=1e-4)

    # lane-batched dispatch with per-lane neighbour rows (the trainer path)
    lanes = kops.community_spmm(a, z, nbr)
    np.testing.assert_allclose(np.asarray(lanes), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_block_csr_roundtrip_and_ell_spmm(sparse_layout):
    g, layout = sparse_layout
    csr = layout.compress()
    assert csr is layout.block_csr          # cached when compressed=True
    assert csr.nnz == layout.nnz_blocks < layout.num_parts ** 2
    np.testing.assert_array_equal(csr.to_dense(), layout.a_blocks)

    rng = np.random.default_rng(1)
    x = rng.normal(size=(g.num_nodes, 5)).astype(np.float32)
    z = layout.pack(x)
    dense = np.einsum("mrip,rpc->mic", layout.a_blocks, z)
    np.testing.assert_allclose(csr.spmm(z), dense, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(layout.unpack(z), x, rtol=0, atol=0)

    zj = jnp.asarray(z)
    ell = kops.community_spmm_ell(jnp.asarray(csr.ell_blocks),
                                  jnp.asarray(csr.ell_indices),
                                  jnp.asarray(csr.ell_mask), zj)
    np.testing.assert_allclose(np.asarray(ell), dense, rtol=1e-4, atol=1e-4)
    oracle = ref.community_spmm_ell_ref(jnp.asarray(csr.ell_blocks),
                                        jnp.asarray(csr.ell_indices),
                                        jnp.asarray(csr.ell_mask), zj)
    np.testing.assert_allclose(np.asarray(oracle), dense,
                               rtol=1e-4, atol=1e-4)

    # compression is where the memory drops: nnz blocks vs M² blocks
    assert csr.blocks.nbytes < layout.a_blocks.nbytes


def test_gather_bytes_accounting(sparse_layout):
    _, layout = sparse_layout
    stats = messages.gather_bytes(layout.neighbor_mask, layout.n_pad, [16, 8])
    assert stats["needed_bytes"] < stats["full_bytes"]
    assert stats["nnz_blocks"] == layout.nnz_blocks
    assert 0.0 < stats["savings_ratio"] < 1.0
    # exact: needed/full == nnz/M²
    ratio = stats["needed_bytes"] / stats["full_bytes"]
    assert ratio == pytest.approx(layout.nnz_blocks / layout.num_parts ** 2)


def test_trainer_kernel_path_carries_mask():
    """use_kernel=True routes rowagg through kops.community_spmm with the
    per-lane neighbour rows (no mask=None call sites) — one ADMM step must
    match the masked-einsum path, both via the CPU ref dispatch and the
    interpret-mode Pallas kernel body."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=3, nodes_per_part=16, attach=1, seed=2, feat_dim=8)
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    base = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0, part=part)
    base.step()

    for interpret in (False, True):
        kops.repro_force_interpret(interpret)
        try:
            kern = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0,
                                       part=part, use_kernel=True)
            kern.step()
        finally:
            kops.repro_force_interpret(False)
        for zb, zk in zip(base.state.zs, kern.state.zs):
            np.testing.assert_allclose(np.asarray(zb), np.asarray(zk),
                                       rtol=2e-4, atol=2e-5)
        for wb, wk in zip(base.state.weights, kern.state.weights):
            np.testing.assert_allclose(np.asarray(wb), np.asarray(wk),
                                       rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_parallel_trainer_masked_matches_dense():
    """The neighbour-masked trainer reaches the same accuracy as a forced
    dense-mask run on a block-sparse community graph (absent blocks are
    zero, so masking must be loss-free) and records the byte savings."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=24, attach=1, seed=1, feat_dim=16)
    cfg = gcn.GCNConfig(layer_dims=(16, 16, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)

    masked = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part)
    assert np.asarray(masked.layout.neighbor_mask).sum() < 16
    assert masked.comm_stats["needed_bytes"] < masked.comm_stats["full_bytes"]

    dense = ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0, part=part)
    dense.data = dataclasses.replace(
        dense.data, neighbor_mask=jnp.ones_like(dense.data.neighbor_mask))

    mlog = masked.train(6)
    dlog = dense.train(6)
    assert np.isfinite(mlog.residual).all()
    assert abs(mlog.test_acc[-1] - dlog.test_acc[-1]) <= 0.05
