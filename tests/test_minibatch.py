"""Stochastic community minibatching: sampler, sub-plan, staleness, and
the sampled trainer itself.

The contract under test: sampling changes WHICH blocks step, never what a
stepped block computes.  ``batch_fraction=1.0`` must reproduce the
full-batch packed trainer bitwise (every minibatch knob is
exact-at-identity: masks of 1.0, decay 1.0, a full-set restricted plan is
the plan).  Under real sampling the restricted exchange carries only
messages into sampled shards, unsampled lanes hold their iterates
bit-for-bit, the staleness weight decays monotonically with age, and the
augmented Lagrangian still descends.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import gcn, graph, messages
from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig, stale_weights
from repro.sharding.partition import CommunityBatchSampler
from repro.util.compat import make_mesh


def _skewed(m=8, seed=0, skew=0.8):
    return graph.synthetic_powerlaw_communities(
        num_parts=m, nodes_per_part=12, attach=1, seed=seed, feat_dim=8,
        size_skew=skew)


def _trainer(g, part, mesh, config):
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    m = int(part.max()) + 1
    return ParallelADMMTrainer(cfg, admm, g, num_parts=m, seed=0,
                               part=part, mesh=mesh, config=config)


# ---------------------------------------------------------------------------
# the batch sampler
# ---------------------------------------------------------------------------

def test_sampler_is_seeded_and_deterministic():
    w = np.array([4.0, 1.0, 2.0, 1.0])
    a = CommunityBatchSampler(4, 0.5, seed=7, weights=w)
    b = CommunityBatchSampler(4, 0.5, seed=7, weights=w)
    assert [a.batch(t) for t in range(8)] == [b.batch(t) for t in range(8)]
    assert a.cycle(3) == b.cycle(3)
    # under uniform weights the seeded permutation decides the batch
    # composition — different seeds must eventually disagree
    u7 = CommunityBatchSampler(6, 0.5, seed=7)
    u8 = CommunityBatchSampler(6, 0.5, seed=8)
    assert any(u7.cycle(i) != u8.cycle(i) for i in range(16))


def test_sampler_covers_every_shard_once_per_cycle():
    s = CommunityBatchSampler(6, 1 / 3, seed=0)
    for c in range(4):
        seen = sorted(x for b in s.cycle(c) for x in b)
        assert seen == list(range(6))
    # batch(t) walks the cycles in order
    flat = [s.batch(t) for t in range(2 * s.num_batches)]
    assert flat[:s.num_batches] == list(s.cycle(0))
    assert flat[s.num_batches:] == list(s.cycle(1))


def test_sampler_balances_by_weight():
    # one dominant shard: the greedy must isolate it rather than pair it
    w = np.array([100.0, 1.0, 1.0, 1.0])
    s = CommunityBatchSampler(4, 0.5, seed=0, weights=w)
    batches = s.cycle(0)
    assert len(batches) == 2
    heavy = [b for b in batches if 0 in b][0]
    assert heavy == (0,)


def test_sampler_clamps_and_validates():
    # num_batches never exceeds n_shards (f -> 0) and f=1 is one batch
    assert CommunityBatchSampler(4, 0.01).num_batches == 4
    assert CommunityBatchSampler(4, 1.0).num_batches == 1
    assert CommunityBatchSampler(1, 0.25).num_batches == 1
    with pytest.raises(ValueError, match="batch_fraction"):
        CommunityBatchSampler(4, 0.0)
    with pytest.raises(ValueError, match="batch_fraction"):
        CommunityBatchSampler(4, 1.5)


# ---------------------------------------------------------------------------
# the restricted exchange plan
# ---------------------------------------------------------------------------

def _plan(n_shards=4):
    g, part = _skewed()
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    return messages.build_neighbor_exchange(
        layout.neighbor_mask, n_shards, layout.n_pad,
        sizes=layout.sizes, row_counts=layout.eff_row_counts())


def test_restrict_exchange_full_set_is_the_plan():
    plan = _plan()
    assert messages.restrict_exchange(plan, {0, 1, 2, 3}) is plan


def test_restrict_exchange_keeps_only_sampled_destinations():
    plan = _plan()
    for sampled in ({0}, {1, 3}, {0, 2}):
        sub = messages.restrict_exchange(plan, sampled)
        pairs = [p for r in sub.rounds for p in r.pairs]
        assert pairs, "restriction emptied a non-empty schedule"
        assert all(dst in sampled for _, dst in pairs)
        # unsampled sources still send into sampled shards
        full_into = {(s, d) for r in plan.rounds for (s, d) in r.pairs
                     if d in sampled}
        assert set(pairs) == full_into
        # geometry is untouched — localized ELL indices stay valid
        assert sub.r_pad == plan.r_pad
        assert sub.n_pad == plan.n_pad
        # wire shrinks
        full_w = messages.exchange_bytes(plan, [8])["wire_bytes"]
        sub_w = messages.exchange_bytes(sub, [8])["wire_bytes"]
        assert sub_w < full_w


def test_restrict_exchange_validates():
    plan = _plan()
    with pytest.raises(ValueError, match="non-empty"):
        messages.restrict_exchange(plan, set())
    with pytest.raises(ValueError, match="out of range"):
        messages.restrict_exchange(plan, {0, 7})


def _layout_and_plan(seed=0, skew=0.8, n_shards=4, packed=True):
    g, part = _skewed(seed=seed, skew=skew)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    plan = messages.build_neighbor_exchange(
        layout.neighbor_mask, n_shards, layout.n_pad,
        sizes=layout.sizes,
        row_counts=layout.eff_row_counts() if packed else None)
    return layout, plan


def test_restrict_exchange_geometry_fuzz():
    """Seeded randomized sweep (hypothesis-free) over graphs, shard
    counts, plan modes and sampled sets.  The load-bearing invariant is
    destination-additivity: every pair has exactly one destination, so
    the sub-plan's true rows (and needed bytes) must equal the sum over
    the singleton restrictions — round padding is the only non-additive
    quantity, and it only ever shrinks."""
    rng = np.random.default_rng(1234)
    for trial in range(8):
        seed = int(rng.integers(0, 100))
        skew = float(rng.uniform(0.0, 1.2))
        n_shards = int(rng.choice([2, 4, 8]))
        packed = bool(rng.integers(0, 2))
        _, plan = _layout_and_plan(seed=seed, skew=skew,
                                   n_shards=n_shards, packed=packed)
        full_pairs = {p for r in plan.rounds for p in r.pairs}
        full_eb = messages.exchange_bytes(plan, [8])
        singles = {d: messages.restrict_exchange(plan, {d})
                   for d in range(n_shards)}
        for _ in range(4):
            k = int(rng.integers(1, n_shards + 1))
            sampled = set(int(s) for s in
                          rng.choice(n_shards, size=k, replace=False))
            sub = messages.restrict_exchange(plan, sampled)
            # pairs are exactly the full set filtered by destination
            sub_pairs = {p for r in sub.rounds for p in r.pairs}
            assert sub_pairs == {p for p in full_pairs
                                 if p[1] in sampled}, (trial, sampled)
            # geometry untouched: localized indices stay valid
            assert sub.r_pad == plan.r_pad
            assert sub.n_pad == plan.n_pad
            assert sub.needed_ids == plan.needed_ids
            assert sub.row_counts == plan.row_counts
            assert sub.plane_rows == plan.plane_rows
            assert sub.recv_plane_rows == plan.recv_plane_rows
            # rounds only shrink: pad rows bounded by the source round,
            # slot tables trimmed to the surviving pad width
            by_off = {r.offset: r for r in plan.rounds}
            for r in sub.rounds:
                src = by_off[r.offset]
                assert 0 < r.rows_pad <= src.rows_pad
                assert r.send_idx.shape[1] == r.rows_pad
                assert r.recv_slot.shape[1] == r.rows_pad
            # destination-additivity of the true (padding-free) rows
            eb = messages.exchange_bytes(sub, [8])
            assert eb["true_rows"] == sum(
                messages.exchange_bytes(singles[d], [8])["true_rows"]
                for d in sampled), (trial, sampled)
            assert eb["wire_bytes"] == \
                eb["p2p_needed_bytes"] + eb["padding_bytes"]
            assert eb["wire_bytes"] <= full_eb["wire_bytes"]
            # arrival groups of the sub-schedule stay in range
            arr = messages.arrival_rounds(sub)
            assert arr.min() >= -1
            assert arr.max() < max(sub.num_rounds, 1)


def test_overlap_stats_price_the_restricted_plan():
    """`overlap_stats` on a restricted sub-plan must price exactly that
    sub-plan's scheduled wire: `total_wire_bytes` equals
    `exchange_bytes(sub)["wire_bytes"]` for any payload widths, and the
    exposed share never exceeds the total."""
    layout, plan = _layout_and_plan()
    nbr = layout.neighbor_mask
    for sampled in ({0}, {1, 3}, {0, 2, 3}, {0, 1, 2, 3}):
        sub = messages.restrict_exchange(plan, sampled)
        for cs in ([8], [8, 8, 4], [8, 8, 4, 4, 8, 4, 8]):
            ov = messages.overlap_stats(sub, nbr, cs, enabled=True)
            eb = messages.exchange_bytes(sub, cs)
            assert ov["total_wire_bytes"] == eb["wire_bytes"]
            assert ov["exposed_wire_bytes"] <= ov["total_wire_bytes"]
            assert -1e-9 <= ov["exposed_wire_s"] \
                <= ov["total_wire_s"] + 1e-9
            assert 0.0 <= ov["overlap_efficiency"] <= 1.0
            assert ov["num_groups"] == sub.num_rounds + 1
        # a strict restriction prices strictly less wire than the plan
        if len(sampled) < plan.n_shards:
            full = messages.overlap_stats(plan, nbr, [8], enabled=True)
            rst = messages.overlap_stats(sub, nbr, [8], enabled=True)
            assert rst["total_wire_bytes"] < full["total_wire_bytes"]


# ---------------------------------------------------------------------------
# the staleness weight
# ---------------------------------------------------------------------------

def test_stale_weights_monotone_and_exact_at_zero():
    ages = np.array([0, 1, 2, 5, 10])
    d = np.asarray(stale_weights(ages, 0.5))
    # exactly 1.0 at age 0 — the bitwise f=1.0 parity rests on this
    assert d[0] == np.float32(1.0)
    assert np.all(np.diff(d) < 0)                 # strictly decaying
    np.testing.assert_allclose(d, 0.5 ** ages.astype(np.float32),
                               rtol=1e-6)
    # decay 1.0 disables damping entirely (exact block-coordinate steps)
    np.testing.assert_array_equal(np.asarray(stale_weights(ages, 1.0)),
                                  np.ones(5, np.float32))


# ---------------------------------------------------------------------------
# the sampled trainer, one shard (multi-shard runs in the subprocess)
# ---------------------------------------------------------------------------

def test_fraction_one_matches_packed_bitwise_one_shard():
    """f=1.0 samples every shard every round: W/Z/U and the Lagrangian
    must equal the full-batch packed trainer BITWISE (identity masks and
    decay 1.0 multiply exactly, the full-set sub-plan IS the plan)."""
    g, part = _skewed()
    mesh = make_mesh((1,), (AXIS,))
    ref = _trainer(g, part, mesh, TrainerConfig.packed())
    mb = _trainer(g, part, mesh,
                  TrainerConfig.minibatch(batch_fraction=1.0))
    for _ in range(4):
        ref.step()
        mb.step()
    for zr, zm in zip(ref.state.zs, mb.state.zs):
        np.testing.assert_array_equal(np.asarray(zr), np.asarray(zm))
    np.testing.assert_array_equal(np.asarray(ref.state.u),
                                  np.asarray(mb.state.u))
    for wr, wm in zip(ref.state.weights, mb.state.weights):
        np.testing.assert_array_equal(np.asarray(wr), np.asarray(wm))
    assert float(ref._lagrangian(ref.state)) == \
        float(mb._lagrangian(mb.state))


def test_minibatch_comm_stats_and_age_tracking():
    g, part = _skewed()
    mesh = make_mesh((1,), (AXIS,))
    mb = _trainer(g, part, mesh,
                  TrainerConfig.minibatch(batch_fraction=1.0,
                                          stale_decay=0.75,
                                          sample_seed=3))
    st = mb.comm_stats["minibatch"]
    assert st["enabled"] is True
    assert st["batch_fraction"] == 1.0
    assert st["stale_decay"] == 0.75
    assert st["sample_seed"] == 3
    assert st["num_batches"] == 1                 # one shard -> full batch
    assert st["sampled_state_rows"] == st["full_state_rows"]
    mb.step()
    assert mb.comm_stats["minibatch"]["rounds"] == 1
    # every community sampled every round -> ages pinned at zero
    assert mb.comm_stats["minibatch"]["max_age"] == 0
    assert np.all(mb._ages == 0)
    # the full-batch trainer reports the disabled stub
    full = _trainer(g, part, mesh, TrainerConfig.packed())
    assert full.comm_stats["minibatch"] == {"enabled": False}


# ---------------------------------------------------------------------------
# 4-shard subprocess: bitwise f=1.0, sampled wire < full, Lagrangian
# descent within the gap, and the analysis proof on the sampled step
# ---------------------------------------------------------------------------

_MB_WORKER = r"""
import numpy as np, jax
from repro import analysis
from repro.core import gcn, graph, messages
from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

g, part = graph.synthetic_powerlaw_communities(
    num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
    size_skew=0.8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh = make_mesh((4,), (AXIS,), devices=jax.devices()[:4])

def build(config):
    return ParallelADMMTrainer(cfg, admm, g, num_parts=8, seed=0,
                               part=part, mesh=mesh, config=config)

# --- f=1.0 bitwise parity on 4 shards ---
ref = build(TrainerConfig.packed())
mb1 = build(TrainerConfig.minibatch(batch_fraction=1.0))
for _ in range(3):
    ref.step(); mb1.step()
for zr, zm in zip(ref.state.zs, mb1.state.zs):
    np.testing.assert_array_equal(np.asarray(zr), np.asarray(zm))
np.testing.assert_array_equal(np.asarray(ref.state.u),
                              np.asarray(mb1.state.u))
for wr, wm in zip(ref.state.weights, mb1.state.weights):
    np.testing.assert_array_equal(np.asarray(wr), np.asarray(wm))
assert float(ref._lagrangian(ref.state)) == float(mb1._lagrangian(mb1.state))
print("MB_BITWISE_OK")

# --- sampled run: wire drops, Lagrangian descends within the gap ---
mb = build(TrainerConfig.minibatch(batch_fraction=0.5))
st = mb.comm_stats["minibatch"]
assert st["enabled"] and st["num_batches"] == 2
assert st["sampled_wire_bytes"] < st["full_wire_bytes"]
assert st["mean_sampled_wire_bytes"] < st["full_wire_bytes"]
seen = sorted(s for b in st["schedule"] for s in b)
assert seen == [0, 1, 2, 3], st["schedule"]
lag0 = float(mb._lagrangian(mb.state))
for _ in range(8):
    mb.step()
lag = float(mb._lagrangian(mb.state))
assert lag < lag0, (lag0, lag)
lag_full = float(ref._lagrangian(ref.state))
for _ in range(5):
    ref.step()
lag_full = float(ref._lagrangian(ref.state))
# pinned gap: the sampled Lagrangian lands within 50% of full batch
# after the same 8 rounds (the benchmark pins 25% at M=32)
assert lag <= lag_full + 0.5 * abs(lag_full), (lag, lag_full)
# unsampled lanes aged, resampled lanes reset
assert mb._ages.max() >= 0 and mb._round == 8
assert len(mb._mb_steps) == 2          # one program per distinct batch
print("MB_SAMPLED_OK")

# --- the compiled sampled step's collectives are exactly the sub-plan ---
sampled = set(mb._sampler.batch(mb._round - 1))
sub_pairs = {p for r in mb._active_plan.rounds for p in r.pairs}
full_pairs = {p for r in mb._plan.rounds for p in r.pairs}
assert sub_pairs < full_pairs
assert all(d in sampled for _, d in sub_pairs)
waivers = (analysis.Waiver(
    "pallas/tile-alignment", "packed ELL contracts in 8-row steps",
    when={"state_packed": True}),)
rep = analysis.analyze_trainer(mb, config="p2p_minibatch",
                               waivers=waivers)
assert analysis.no_findings(rep, rule="collective/permute-schedule")
assert analysis.no_findings(rep, rule="collective/no-allgather-under-p2p")
assert not rep.errors(), rep.summary()
print("MB_ANALYSIS_OK")
"""


def test_minibatch_on_4_shards():
    """The acceptance run: f=1.0 bitwise-matches full batch on 4 shards;
    f=0.5 wires strictly less per sampled round, descends the Lagrangian
    to within the pinned gap, and its compiled step's ppermute schedule
    is exactly the restricted sub-plan (no unsampled pair touched)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _MB_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("MB_BITWISE_OK", "MB_SAMPLED_OK", "MB_ANALYSIS_OK"):
        assert tag in out.stdout, out.stdout


# ---------------------------------------------------------------------------
# 4-shard subprocess: overlap composes with sampling — per-sub-plan
# arrival groups, tolerance parity, and per-step overlap re-pricing
# ---------------------------------------------------------------------------

_OV_WORKER = r"""
import numpy as np, jax
import jax.numpy as jnp
from repro.analysis.trainer import _gathered_cs
from repro.core import gcn, graph, messages
from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

g, part = graph.synthetic_powerlaw_communities(
    num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
    size_skew=0.8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh = make_mesh((4,), (AXIS,), devices=jax.devices()[:4])

def build(config):
    return ParallelADMMTrainer(cfg, admm, g, num_parts=8, seed=0,
                               part=part, mesh=mesh, config=config)

# --- overlap=True now composes with batch_fraction < 1 ---
mb = build(TrainerConfig.minibatch(batch_fraction=0.5))
ov = build(TrainerConfig.minibatch(batch_fraction=0.5, overlap=True))
lag0 = float(ov._lagrangian(ov.state))
for _ in range(8):
    mb.step(); ov.step()
lag = float(ov._lagrangian(ov.state))
assert lag < lag0, (lag0, lag)

# same sample_seed -> same schedule; overlap only regroups the neighbour
# sum per arrival round, so the trajectories agree to summation-order
# tolerance
def delta(a, b):
    return max(
        max(float(jnp.max(jnp.abs(x - y)))
            for x, y in zip(a.weights, b.weights)),
        max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(a.zs, b.zs)),
        float(jnp.max(jnp.abs(a.u - b.u))))
d = delta(mb.state, ov.state)
assert d <= 1e-4, f"overlap x minibatch parity {d}"
print("OV_MB_PARITY_OK")

# --- comm_stats["overlap"] prices the ACTIVE restricted plan ---
st = ov.comm_stats["overlap"]
assert st["enabled"] is True
sub = ov._active_plan
sub_pairs = {p for r in sub.rounds for p in r.pairs}
full_pairs = {p for r in ov._plan.rounds for p in r.pairs}
assert sub_pairs < full_pairs          # a strict sub-schedule is active
eb = messages.exchange_bytes(sub, _gathered_cs(ov.cfg))
assert st["total_wire_bytes"] == eb["wire_bytes"], (st, eb)
assert st["exposed_wire_bytes"] <= st["total_wire_bytes"]
assert st["num_groups"] == sub.num_rounds + 1
print("OV_MB_PRICED_OK")
"""


def test_overlap_composes_with_minibatch_on_4_shards():
    """overlap=True + batch_fraction=0.5 trains (Lagrangian descends),
    stays within summation-order tolerance of the non-overlap sampled
    trainer, and `comm_stats["overlap"]` re-prices the active restricted
    sub-plan — its total equals that sub-plan's `exchange_bytes` wire,
    with the exposed share bounded by it."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _OV_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("OV_MB_PARITY_OK", "OV_MB_PRICED_OK"):
        assert tag in out.stdout, out.stdout
