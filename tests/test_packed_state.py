"""Packed ragged device state: the Σ-bucket-rows resident plane, the
offset-indexed exchange/aggregation path, and the double-buffered
exchange/aggregation overlap.

The invariant under test everywhere: packing changes where rows LIVE,
never the math — the packed trainer's iterates are *bitwise* equal to the
strided (M, n_pad, ...) path's on CPU (the zero-outside-counts contract
makes pack/unpack lossless and the einsum oracles see identical operands),
while ``comm_stats['state']`` shows resident rows/bytes dropping.  The
overlap mode re-associates the neighbour sum by arrival round, so its
parity is tolerance- rather than bit-level; its wire schedule is
byte-identical and ``comm_stats['overlap']`` prices what stays exposed.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import analysis
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh


def _skewed(m=8, seed=0, skew=0.8):
    return graph.synthetic_powerlaw_communities(
        num_parts=m, nodes_per_part=12, attach=1, seed=seed, feat_dim=8,
        size_skew=skew)


def _trainer(g, part, mesh, **kw):
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    m = int(part.max()) + 1
    kw.setdefault("compressed", True)
    return ParallelADMMTrainer(cfg, admm, g, num_parts=m, seed=0,
                               part=part, mesh=mesh,
                               pad_mode="bucketed", **kw)


# ---------------------------------------------------------------------------
# device layout geometry
# ---------------------------------------------------------------------------

def test_device_layout_matches_plan_geometry():
    """The device layout and the exchange plan derive local offsets and
    plane heights from the same bucket counts — a shard's send plane IS
    its resident state plane, no re-staging between them."""
    from repro.core import messages
    g, part = _skewed()
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    dl = layout.device_layout(4)
    plan = messages.build_neighbor_exchange(
        layout.neighbor_mask, 4, layout.n_pad, sizes=layout.sizes,
        row_counts=layout.eff_row_counts())
    assert plan.plane_rows == dl.plane_rows
    np.testing.assert_array_equal(plan.local_offsets, dl.local_offsets)
    np.testing.assert_array_equal(plan.row_counts, dl.row_counts)
    # skew actually bites: the packed stack is strictly shorter
    assert dl.total_rows < 8 * layout.n_pad


def test_global_unpack_rows_is_the_scatter_inverse():
    g, part = _skewed()
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    dl = layout.device_layout(2)
    rng = np.random.default_rng(0)
    blocked = layout.pack(
        rng.normal(size=(g.num_nodes, 3)).astype(np.float32))
    packed = dl.pack_state(blocked)
    # the (M·n_pad,) gather table reproduces unpack_state via take-fill
    idx = dl.global_unpack_rows()
    padded = np.concatenate([packed, np.zeros((1, 3), np.float32)])
    via_table = padded[np.minimum(idx, dl.total_rows)].reshape(
        dl.num_parts, layout.n_pad, 3)
    np.testing.assert_array_equal(via_table, dl.unpack_state(packed))
    assert dl.state_rows() == dl.total_rows
    assert dl.state_rows(strided=True) == dl.num_parts * layout.n_pad


# ---------------------------------------------------------------------------
# trainer validation + comm_stats accounting
# ---------------------------------------------------------------------------

def test_packed_flag_validation():
    g, part = _skewed()
    mesh = make_mesh((1,), (AXIS,))
    with pytest.raises(ValueError, match="compressed"):
        _trainer(g, part, mesh, packed=True, compressed=False)
    with pytest.raises(ValueError, match="p2p"):
        _trainer(g, part, mesh, packed=True, transport="allgather")
    with pytest.raises(ValueError, match="packed"):
        _trainer(g, part, mesh, overlap=True)


def test_comm_stats_state_accounting():
    g, part = _skewed()
    mesh = make_mesh((1,), (AXIS,))
    tr = _trainer(g, part, mesh, packed=True)
    st = tr.comm_stats["state"]
    assert st["packed"] is True
    assert st["node_rows"] <= st["bucket_rows"] <= st["rows"] \
        <= st["strided_rows"]
    assert st["rows"] < st["strided_rows"]          # the skew pays off
    assert st["z_bytes"] < st["z_strided_bytes"]
    assert st["resident_bytes"] < st["strided_equiv_bytes"]
    # the strided trainer reports the same schema with packed=False and
    # rows at the full M·n_pad stride
    ref = _trainer(g, part, mesh).comm_stats["state"]
    assert ref["packed"] is False
    assert ref["rows"] == ref["strided_rows"] == st["strided_rows"]


# ---------------------------------------------------------------------------
# single-shard bitwise parity (the multi-shard run is the subprocess below)
# ---------------------------------------------------------------------------

def test_packed_trainer_bitwise_matches_strided_one_shard():
    """On one shard the packed trainer stores Z/U as packed planes but
    runs the identical blocked math — every iterate, the Lagrangian and
    the metrics must match the strided trainer BITWISE."""
    g, part = _skewed()
    mesh = make_mesh((1,), (AXIS,))
    ref = _trainer(g, part, mesh)
    pk = _trainer(g, part, mesh, packed=True)
    dl = pk.packed_layout
    assert dl is not None
    for _ in range(4):
        ref.step()
        pk.step()
    for zr, zp in zip(ref.state.zs, pk.state.zs):
        assert zp.shape[0] == dl.total_rows
        np.testing.assert_array_equal(np.asarray(zr),
                                      dl.unpack_state(np.asarray(zp)))
    np.testing.assert_array_equal(np.asarray(ref.state.u),
                                  dl.unpack_state(np.asarray(pk.state.u)))
    for wr, wp in zip(ref.state.weights, pk.state.weights):
        np.testing.assert_array_equal(np.asarray(wr), np.asarray(wp))
    assert float(ref._lagrangian(ref.state)) == \
        float(pk._lagrangian(pk.state))
    for a, b in zip(ref._metrics(ref.state), pk._metrics(pk.state)):
        assert float(a) == float(b)


# ---------------------------------------------------------------------------
# the packed-resident-state analysis rule
# ---------------------------------------------------------------------------

def _hlo(body: str) -> str:
    return ("HloModule test\n\n"
            "ENTRY %main (p0: f32[8,8]) -> f32[8,8] {\n"
            + body + "\n}\n")


def test_packed_resident_state_rule_fires_on_blocked_stacks():
    exp = {"n_pad": 16, "state_packed": True, "packed_rows_bound": 4}
    # a computed (8, 16, 7) blocked row stack: 8 rows > r_pad = 4
    text = _hlo(
        "  %p0 = f32[8,16,7]{2,1,0} parameter(0)\n"
        "  ROOT %b = f32[8,16,7]{2,1,0} negate(f32[8,16,7]{2,1,0} %p0)")
    rep = analysis.analyze_hlo(text, expectations=exp)
    hits = rep.findings_for("memory/packed-resident-state")
    assert len(hits) == 1 and hits[0].location == "b"
    assert hits[0].severity.name == "ERROR"
    # parameters may hold the closed-over blocked store
    assert not any(f.location == "p0" for f in hits)
    # within the receive-view bound: silent
    ok = _hlo(
        "  %p0 = f32[4,16,7]{2,1,0} parameter(0)\n"
        "  ROOT %b = f32[4,16,7]{2,1,0} negate(f32[4,16,7]{2,1,0} %p0)")
    assert not analysis.analyze_hlo(ok, expectations=exp).findings_for(
        "memory/packed-resident-state")
    # (rows, n_pad, n_pad) is an adjacency block stack — the dense-
    # adjacency rule's turf, not this one's
    adj = _hlo(
        "  %p0 = f32[8,16,16]{2,1,0} parameter(0)\n"
        "  ROOT %b = f32[8,16,16]{2,1,0} negate(f32[8,16,16]{2,1,0} %p0)")
    assert not analysis.analyze_hlo(adj, expectations=exp).findings_for(
        "memory/packed-resident-state")
    # unpacked configs are out of scope
    off = analysis.analyze_hlo(
        text, expectations=dict(exp, state_packed=False))
    assert not off.findings_for("memory/packed-resident-state")


# ---------------------------------------------------------------------------
# 4-shard subprocess: packed p2p vs strided bitwise, overlap tolerance,
# and the compiled-program proof (analysis rules over the real HLO)
# ---------------------------------------------------------------------------

_PACKED_WORKER = r"""
import jax
import numpy as np
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer
from repro.core.serial import SerialADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

N_SHARDS = 4
assert len(jax.devices()) >= N_SHARDS, jax.devices()
g, part = graph.synthetic_powerlaw_communities(
    num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
    size_skew=0.8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh = make_mesh((N_SHARDS,), (AXIS,), devices=jax.devices()[:N_SHARDS])

def build(**kw):
    return ParallelADMMTrainer(cfg, admm, g, num_parts=8, seed=0,
                               part=part, mesh=mesh, compressed=True,
                               pad_mode="bucketed", **kw)

serial = SerialADMMTrainer(cfg, admm, g, seed=0)
ref = build()
pk = build(packed=True)
ov = build(packed=True, overlap=True)
dl = pk.packed_layout

# resident-state accounting: packed planes strictly undercut the stride
st = pk.comm_stats["state"]
assert st["packed"] and st["rows"] < st["strided_rows"], st
assert st["z_bytes"] < st["z_strided_bytes"], st
# wire schedule identical either way; overlap prices the exposed share
assert pk.comm_stats["wire_bytes"] == ref.comm_stats["wire_bytes"]
assert not pk.comm_stats["overlap"]["enabled"]
ost = ov.comm_stats["overlap"]
assert ost["enabled"] and ost["overlap_efficiency"] > 0, ost
assert ost["exposed_wire_s"] < ost["total_wire_s"], ost
print("STATS_OK")

for _ in range(3):
    serial.step(); ref.step(); pk.step(); ov.step()

# packed p2p == strided p2p BITWISE (pack/unpack is lossless and the
# math never sees the relocation)
for zr, zp in zip(ref.state.zs, pk.state.zs):
    np.testing.assert_array_equal(np.asarray(zr),
                                  dl.unpack_state(np.asarray(zp)))
np.testing.assert_array_equal(np.asarray(ref.state.u),
                              dl.unpack_state(np.asarray(pk.state.u)))
for wr, wp in zip(ref.state.weights, pk.state.weights):
    np.testing.assert_array_equal(np.asarray(wr), np.asarray(wp))
assert float(ref._lagrangian(ref.state)) == float(pk._lagrangian(pk.state))
print("PACKED_BITWISE_OK")

# overlap re-associates the neighbour sum by arrival group: tolerance
for zp, zo in zip(pk.state.zs, ov.state.zs):
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zo),
                               rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(np.asarray(pk.state.u), np.asarray(ov.state.u),
                           rtol=2e-4, atol=2e-5)
lp, lo = float(pk._lagrangian(pk.state)), float(ov._lagrangian(ov.state))
assert abs(lp - lo) <= 1e-4 * max(1.0, abs(lp)), (lp, lo)
print("OVERLAP_OK")

# both packed trainers reproduce the SERIAL trainer's W/Z/U + Lagrangian
lag_s = float(serial._lagr(serial.a_tilde, serial.z0, serial.labels,
                           serial.train_mask, serial.state))
for tr in (pk, ov):
    for zs_, zp in zip(serial.state.zs, tr.state.zs):
        np.testing.assert_allclose(
            np.asarray(zs_),
            tr.layout.unpack(dl.unpack_state(np.asarray(zp))),
            rtol=2e-3, atol=2e-4)
    for ws, wp in zip(serial.state.weights, tr.state.weights):
        np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                                   rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(serial.state.u),
        tr.layout.unpack(dl.unpack_state(np.asarray(tr.state.u))),
        rtol=2e-3, atol=2e-4)
    lag_t = float(tr._lagrangian(tr.state))
    assert abs(lag_s - lag_t) <= 1e-4 * max(1.0, abs(lag_s)), (lag_s, lag_t)
print("SERIAL_PARITY_OK")

# compiled-program proof: the packed step holds no blocked row stack
# taller than r_pad, keeps the gather-free p2p schedule, and the 8-row
# ELL tile quantum is the only alignment deviation (warning, waived)
from repro import analysis
for tr, name in ((pk, "packed"), (ov, "packed-overlap")):
    rep = analysis.analyze_trainer(tr, config=name)
    assert analysis.no_findings(rep, rule="memory/packed-resident-state")
    assert analysis.no_findings(rep,
                                rule="collective/no-allgather-under-p2p")
    assert not rep.errors(), rep.summary()
print("HLO_OK")
"""


def test_packed_p2p_matches_strided_on_4_shards():
    """The acceptance run: a 4-shard packed trainer on the size-skewed
    graph matches the strided trainer's W/Z/U and Lagrangian BITWISE
    after 3 iterations, the overlap trainer matches to tolerance, the
    resident state strictly undercuts the stride, and the compiled step
    passes the packed-resident-state rule."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _PACKED_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("STATS_OK", "PACKED_BITWISE_OK", "OVERLAP_OK",
                "SERIAL_PARITY_OK", "HLO_OK"):
        assert tag in out.stdout, out.stdout


def test_gather_tables_are_memoized():
    """The static pack/unpack gather tables are built once and reused —
    the serving engine and the trainer hot path re-read them every call."""
    g, part = _skewed()
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    dl = layout.device_layout(2)
    assert dl.global_unpack_rows() is dl.global_unpack_rows()
    assert dl.global_pack_rows() is dl.global_pack_rows()
    # memoization must not leak across instances
    dl2 = layout.device_layout(2)
    assert dl2.global_unpack_rows() is not dl.global_unpack_rows()
    np.testing.assert_array_equal(dl2.global_unpack_rows(),
                                  dl.global_unpack_rows())
