"""TrainerConfig: the one home of every trainer mode flag.

The matrix test pins the contract the API redesign promised: every
invalid flag combination the old ``ParallelADMMTrainer.__init__`` inline
checks rejected still raises — from ``TrainerConfig.__post_init__`` now —
with the *identical* message, through every construction path (direct
config, presets, the deprecated old-kwargs shim).  The shim itself must
resolve to the same config the explicit path builds and fire a
DeprecationWarning exactly once.
"""
import argparse
import warnings

import pytest

from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh


def _graph():
    return graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
        size_skew=0.5)


def _trainer(config=None, **kw):
    g, part = _graph()
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    mesh = make_mesh((1,), (AXIS,))
    return ParallelADMMTrainer(cfg, admm, g, num_parts=4, seed=0,
                               part=part, mesh=mesh, config=config, **kw)


# ---------------------------------------------------------------------------
# the validation matrix: every constraint of the historic inline ladder,
# with the exact message it has always raised
# ---------------------------------------------------------------------------

INVALID = [
    (dict(transport="bogus"),
     "unknown transport 'bogus'; expected 'p2p' or 'allgather'"),
    (dict(transport="p2p", compressed=False),
     "transport='p2p' requires compressed=True — the dense Z-coupling "
     "reads all M payload rows"),
    (dict(packed=True, compressed=False),
     "packed=True requires compressed=True — the packed plane is only "
     "routed through ELL offsets, never a dense Z-coupling"),
    (dict(packed=True, compressed=True, transport="allgather"),
     "packed=True requires transport='p2p' — the plane layout exists to "
     "feed the row-exact exchange; an all-gather would re-materialise "
     "the strided (M, n_pad, C) payload"),
    (dict(overlap=True),
     "overlap=True requires packed=True — the staged exchange snapshots "
     "are packed planes"),
    (dict(pad_mode="weird"),
     "unknown pad_mode 'weird'; expected 'global' or 'bucketed'"),
    (dict(adjacency_bf16=True, compressed=False),
     "adjacency_bf16=True requires compressed=True"),
    (dict(compressed=True, packed=True, batch_fraction=0.0),
     "batch_fraction must be in (0, 1], got 0.0"),
    (dict(compressed=True, packed=True, batch_fraction=1.5),
     "batch_fraction must be in (0, 1], got 1.5"),
    (dict(compressed=True, batch_fraction=0.5),
     "batch_fraction requires packed=True — the sampled sweep runs on "
     "the sampled shards' packed planes"),
    (dict(stale_decay=0.0),
     "stale_decay must be in (0, 1], got 0.0"),
    (dict(stale_decay=1.5),
     "stale_decay must be in (0, 1], got 1.5"),
]


@pytest.mark.parametrize("kw,msg", INVALID,
                         ids=[m.split(" — ")[0].split(";")[0]
                              for _, m in INVALID])
def test_invalid_combos_raise_from_config(kw, msg):
    with pytest.raises(ValueError) as e:
        TrainerConfig(**kw)
    assert str(e.value) == msg


@pytest.mark.parametrize(
    "kw,msg", [(k, m) for k, m in INVALID if set(k) <= {
        "transport", "compressed", "packed", "overlap", "pad_mode",
        "adjacency_bf16"}],
    ids=[m.split(" — ")[0].split(";")[0] for k, m in INVALID if set(k) <= {
        "transport", "compressed", "packed", "overlap", "pad_mode",
        "adjacency_bf16"}])
def test_invalid_combos_raise_through_the_shim(kw, msg):
    """The old-kwargs path fails with the same message the inline checks
    produced — validation moved, behaviour did not."""
    with pytest.raises(ValueError) as e, \
            pytest.warns(DeprecationWarning, match="TrainerConfig"):
        _trainer(**kw)
    assert str(e.value) == msg


# ---------------------------------------------------------------------------
# transport resolution + presets
# ---------------------------------------------------------------------------

def test_transport_none_resolution():
    assert TrainerConfig().transport == "allgather"
    assert TrainerConfig(compressed=True).transport == "p2p"


def test_presets():
    d = TrainerConfig.dense()
    assert (d.compressed, d.transport) == (False, "allgather")
    p = TrainerConfig.p2p()
    assert (p.compressed, p.transport, p.packed) == (True, "p2p", False)
    k = TrainerConfig.packed()
    assert (k.compressed, k.transport, k.packed) == (True, "p2p", True)
    mb = TrainerConfig.minibatch()
    assert mb.packed and mb.batch_fraction == 0.25
    assert TrainerConfig.minibatch(batch_fraction=0.5).batch_fraction == 0.5
    # presets accept overrides without re-stating the ladder
    assert TrainerConfig.packed(comm_bf16=True).comm_bf16 is True
    # overlap composes with sampling (per-sub-plan arrival groups) and
    # with the fused kernel; fused without packed stays rejected
    ov = TrainerConfig.minibatch(batch_fraction=0.5, overlap=True)
    assert ov.overlap and ov.batch_fraction == 0.5
    fu = TrainerConfig.packed(fused=True, overlap=True)
    assert fu.fused and fu.overlap


def test_config_is_frozen():
    import dataclasses
    cfg = TrainerConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.compressed = True


def test_from_cli_args_reads_dest_names():
    ns = argparse.Namespace(compressed=True, transport="p2p",
                            pad_mode="bucketed", packed=True,
                            batch_fraction=0.5, stale_decay=0.75,
                            sample_seed=3, unrelated="ignored")
    cfg = TrainerConfig.from_cli_args(ns)
    assert cfg == TrainerConfig(compressed=True, transport="p2p",
                                packed=True, batch_fraction=0.5,
                                stale_decay=0.75, sample_seed=3)
    # missing attributes keep field defaults
    assert TrainerConfig.from_cli_args(argparse.Namespace()) \
        == TrainerConfig()


# ---------------------------------------------------------------------------
# the deprecation shim
# ---------------------------------------------------------------------------

def test_shim_resolves_to_the_same_config_and_warns():
    with pytest.warns(DeprecationWarning, match="TrainerConfig"):
        old = _trainer(compressed=True, transport="p2p", packed=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        new = _trainer(config=TrainerConfig.packed())  # no warning
    assert old.config == new.config == TrainerConfig.packed()
    # resolved trainer attributes agree too
    for attr in ("compressed", "transport", "packed", "overlap",
                 "pad_mode"):
        assert getattr(old, attr) == getattr(new, attr)


def test_shim_rejects_config_plus_legacy_and_unknown_kwargs():
    with pytest.raises(ValueError, match="not both"):
        _trainer(config=TrainerConfig(), compressed=True)
    with pytest.raises(TypeError, match="unexpected keyword"):
        _trainer(bogus_flag=True)


def test_default_construction_warns_nothing():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        tr = _trainer()
    assert tr.config == TrainerConfig()
    assert tr.comm_stats["minibatch"] == {"enabled": False}
