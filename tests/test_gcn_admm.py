"""ADMM core behaviour tests: convergence, message faithfulness, serial vs
parallel agreement (the paper's 'no performance loss' claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gcn, graph, messages, subproblems
from repro.core.serial import BaselineTrainer, SerialADMMTrainer
from repro.core.subproblems import ADMMConfig


@pytest.fixture(scope="module")
def tiny():
    g = graph.synthetic_sbm("amazon_photo_mini", seed=0)
    cfg = gcn.GCNConfig(layer_dims=(745, 64, 8))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    return g, cfg, admm


def test_forward_shapes(tiny):
    g, cfg, _ = tiny
    a = jnp.asarray(graph.normalized_adjacency(g.num_nodes, g.edges))
    ws = gcn.init_weights(cfg, jax.random.key(0))
    zs = gcn.forward(cfg, a, jnp.asarray(g.features), ws)
    assert zs[0].shape == (g.num_nodes, 64)
    assert zs[1].shape == (g.num_nodes, 8)
    assert all(np.isfinite(np.asarray(z)).all() for z in zs)


def test_serial_admm_decreases_lagrangian_and_learns(tiny):
    g, cfg, admm = tiny
    tr = SerialADMMTrainer(cfg, admm, g, seed=0)
    log = tr.train(15)
    assert log.train_acc[-1] > 0.6, log.train_acc
    assert log.test_acc[-1] > 0.6
    assert np.isfinite(log.lagrangian).all()


@pytest.mark.slow
def test_parallel_matches_serial_one_community(tiny):
    """M=1 parallel == serial (same subproblems, one agent)."""
    from repro.core.parallel import ParallelADMMTrainer
    g, cfg, admm = tiny
    s = SerialADMMTrainer(cfg, admm, g, seed=0)
    p = ParallelADMMTrainer(cfg, admm, g, num_parts=1, seed=0)
    for _ in range(3):
        s.step()
        p.step()
    for ws, wp in zip(s.state.weights, p.state.weights):
        np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                                   rtol=2e-4, atol=2e-6)
    z_s = np.asarray(s.state.zs[-1])
    z_p = p.layout.unpack(np.asarray(p.state.zs[-1]))
    np.testing.assert_allclose(z_s, z_p, rtol=2e-3, atol=2e-4)


@pytest.mark.slow
def test_parallel_communities_converge(tiny):
    """M=3 parallel ADMM reaches comparable accuracy to serial (paper §4.2:
    kept inter-community edges => no performance loss)."""
    from repro.core.parallel import ParallelADMMTrainer
    g, cfg, admm = tiny
    s = SerialADMMTrainer(cfg, admm, g, seed=0)
    p = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0)
    slog = s.train(15)
    plog = p.train(15)
    assert plog.test_acc[-1] > 0.6
    assert abs(plog.test_acc[-1] - slog.test_acc[-1]) < 0.15


def test_w_update_identical_serial_vs_parallel(tiny):
    """The W subproblem is a global objective in both trainers — first
    iteration W updates must agree to float tolerance."""
    from repro.core.parallel import ParallelADMMTrainer
    g, cfg, admm = tiny
    s = SerialADMMTrainer(cfg, admm, g, seed=0)
    p = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0)
    s.step()
    p.step()
    for ws, wp in zip(s.state.weights, p.state.weights):
        np.testing.assert_allclose(np.asarray(ws), np.asarray(wp),
                                   rtol=1e-4, atol=1e-6)


def test_message_identities(tiny):
    """Appendix A eq. 4: relayed second-order info equals the literal
    per-neighbour message formulas, and neighbour pre-activations equal the
    global aggregation."""
    g, cfg, admm = tiny
    m = 3
    part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part)
    rng = np.random.default_rng(0)
    n_pad = layout.n_pad
    c_l, c_next = 16, 12
    z_all = jnp.asarray(rng.normal(size=(m, n_pad, c_l)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(c_l, c_next)).astype(np.float32))
    a_blocks = jnp.asarray(layout.a_blocks)

    for me in range(m):
        a_row = a_blocks[me]                       # Ã_{me, ·}
        # p_{l,r→me} = Ã_{me,r} Z_r W
        p = messages.first_order_messages(a_row, z_all, w)
        for r in range(m):
            expect = layout.a_blocks[me, r] @ np.asarray(z_all[r]) @ np.asarray(w)
            np.testing.assert_allclose(np.asarray(p[r]), expect, atol=1e-4)
        # q_me = Σ_r p_{l,r→me}
        q = messages.relay_aggregate(a_row, z_all, w)
        np.testing.assert_allclose(np.asarray(q), np.asarray(p.sum(0)),
                                   atol=1e-4)

    # s²_{l,r→me} = q_r − Ã_{r,me} Z_me W  ==  Σ_{r'≠me} Ã_{r,r'} Z_r' W
    me = 0
    q_all = jnp.stack([messages.relay_aggregate(a_blocks[r], z_all, w)
                       for r in range(m)])
    s2 = messages.second_order_from_relay(q_all, a_blocks[me], z_all[me], w)
    for r in range(m):
        expect = sum(layout.a_blocks[r, rp] @ np.asarray(z_all[rp])
                     for rp in range(m) if rp != me) @ np.asarray(w)
        np.testing.assert_allclose(np.asarray(s2[r]), expect, atol=1e-4)

    # neighbour pre-activations at z_var = z_ref reduce to q_all
    pre = messages.neighbor_preactivations(q_all, a_blocks[me], z_all[me],
                                           z_all[me], w)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(q_all), atol=1e-5)


def test_fista_solves_prox(tiny):
    """FISTA on eq. (7) decreases its objective and beats the init."""
    g, cfg, admm = tiny
    rng = np.random.default_rng(0)
    n, c = 64, 8
    b = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    u = jnp.asarray(0.01 * rng.normal(size=(n, c)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, c, n).astype(np.int32))
    mask = jnp.ones((n,), jnp.float32)
    z0 = jnp.zeros((n, c))

    def obj(z):
        r = z - b
        return (gcn.masked_cross_entropy(z, labels, mask)
                + jnp.vdot(u, r) + 0.5 * admm.rho * jnp.vdot(r, r))

    admm_hi = ADMMConfig(nu=admm.nu, rho=admm.rho, fista_iters=25)
    z = subproblems.fista_last_z(admm_hi, b, u, labels, mask, z0)
    assert float(obj(z)) < float(obj(z0)) - 1e-3


def test_baseline_optimizers_learn(tiny):
    g, cfg, _ = tiny
    for opt, lr in [("adam", 1e-3), ("adagrad", 1e-3), ("gd", 1e-1)]:
        tr = BaselineTrainer(cfg, g, opt, lr, seed=0)
        log = tr.train(10)
        assert log.train_acc[-1] > log.train_acc[0], opt


def test_backtracking_satisfies_majorization():
    """Accepted τ satisfies the paper's P ≥ φ condition."""
    admm = ADMMConfig()
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(20, 20)).astype(np.float32))

    def obj(x):
        r = a @ x - 1.0
        return jnp.vdot(r, r).real

    x0 = jnp.asarray(rng.normal(size=(20, 5)).astype(np.float32))
    x1, tau = subproblems.backtracking_step(obj, x0, jnp.asarray(1.0), admm)
    val, grad = jax.value_and_grad(obj)(x0)
    p_val = val - 0.5 * jnp.vdot(grad, grad).real / tau
    assert float(obj(x1)) <= float(p_val) * (1 + 1e-5) + 1e-6
    assert float(obj(x1)) < float(val)
