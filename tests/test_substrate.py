"""Substrate unit tests: optimizers, checkpointing, data pipeline, sharding
rules, HLO census."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.05),
                                     ("adam", 0.05), ("adagrad", 0.3),
                                     ("adadelta", 2.0)])
def test_optimizers_minimize_quadratic(name, lr):
    opt = optimizers.make(name, lr)
    x = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    state = opt.init(x)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss)(x)
        upd, state = opt.update(g, state, x)
        x = jax.tree.map(lambda a, u: a + u, x, upd)
    assert float(loss(x)) < 0.05, (name, float(loss(x)))


def test_adam_moments_are_f32_for_bf16_params():
    opt = optimizers.make("adam", 1e-3)
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    upd, state = opt.update(g, state, params)
    assert upd["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ckpt
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
            "scalar": jnp.asarray(2.5)}
    ckpt.save(tmp_path, tree, step=7)
    like = jax.tree.map(lambda l: jnp.zeros_like(l), tree)
    restored = ckpt.restore(tmp_path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_mismatch(tmp_path):
    from repro import checkpoint as ckpt
    tree = {"a": jnp.ones((2,))}
    ckpt.save(tmp_path, tree, step=1)
    ckpt.save(tmp_path, tree, step=5)
    assert ckpt.latest_step(tmp_path) == 5
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"a": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_token_stream_is_learnable_and_shaped():
    from repro.data import synthetic_token_batches
    it = synthetic_token_batches(vocab_size=97, batch=4, seq_len=32, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["targets"].shape == (4, 32)
    assert b["tokens"].max() < 97 and b["tokens"].min() >= 0
    # targets are the shifted stream
    b2 = next(it)
    assert not np.array_equal(b["tokens"], b2["tokens"])


def test_pipeline_places_batches():
    from repro.data import TokenPipeline, synthetic_token_batches
    src = synthetic_token_batches(50, 4, 16, seed=1)
    pipe = TokenPipeline(src, mesh=None)
    b = next(pipe)
    assert isinstance(b["tokens"], jax.Array)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_rules():
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.models.build import make_model
    from repro.sharding import partition
    from repro.util.compat import make_mesh

    n = len(jax.devices())
    mesh = make_mesh((1, n), ("data", "model"), devices=jax.devices())
    cfg = get_config("deepseek-moe-16b")      # full config, abstract only
    model = make_model(cfg)
    params_s = jax.eval_shape(model.init, jax.random.key(0))
    specs = partition.param_specs(cfg, mesh, params_s)
    flat = {"/".join(str(getattr(k, "key", k)) for k in path): spec
            for path, spec in
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    # expert weights: E axis over model
    moe_keys = [k for k in flat if "w_gate" in k]
    assert moe_keys and all(flat[k][1] == "model" for k in moe_keys)
    # norms replicated
    norm_keys = [k for k in flat if "norm" in k and "scale" in k]
    assert norm_keys and all(
        all(s is None for s in flat[k]) for k in norm_keys)
    # embedding vocab over model
    emb = [k for k in flat if k.endswith("table")]
    assert emb and flat[emb[0]][0] == "model"


# ---------------------------------------------------------------------------
# HLO census (roofline source of truth)
# ---------------------------------------------------------------------------

def test_hlo_census_counts_scan_trips():
    from repro.launch.roofline import hlo_census

    def f(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, params)
        return c.sum()

    params = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    hlo = jax.jit(f).lower(params, x).compile().as_text()
    census = hlo_census(hlo)
    assert census.flops == 5 * 2 * 16 ** 3
    assert 5 in census.while_trips


def test_hlo_census_collectives():
    from jax.sharding import PartitionSpec as P
    from repro.launch.roofline import hlo_census
    from repro.util import shard_map
    from repro.util.compat import make_mesh
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device")
    mesh = make_mesh((n,), ("d",), devices=jax.devices())

    def g(x):
        return shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                         in_specs=P("d"), out_specs=P(),
                         check_rep=False)(x)

    x = jax.ShapeDtypeStruct((n, 64), jnp.float32)
    hlo = jax.jit(g).lower(x).compile().as_text()
    census = hlo_census(hlo)
    assert census.collectives["all-reduce"]["count"] >= 1
    assert census.collective_bytes >= 64 * 4


def test_roofline_terms_pick_dominant():
    from repro.launch.roofline import roofline_terms
    t = roofline_terms(flops=197e12, hbm_bytes=1.0, collective_total=1.0)
    assert t["dominant"] == "compute_s"
    t = roofline_terms(flops=1.0, hbm_bytes=819e9 * 5, collective_total=1.0)
    assert t["dominant"] == "memory_s"


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def test_schedules():
    from repro.optim import schedules
    cos = schedules.make("cosine", total_steps=100, warmup_steps=10)
    assert float(cos(0)) < float(cos(9)) <= 1.0          # warming up
    assert abs(float(cos(10)) - 1.0) < 0.02              # peak after warmup
    assert float(cos(99)) < 0.15                         # decayed
    warm = schedules.make("warmup", 0, warmup_steps=5)
    assert float(warm(0)) == pytest.approx(0.2)
    assert float(warm(10)) == 1.0
