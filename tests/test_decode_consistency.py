"""Integration: token-by-token cached decode reproduces the full forward
pass — exercises KV caches, MLA latent cache, SSD state relay, RG-LRU
recurrence and conv streaming against the chunked full-sequence path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.build import make_model

TEXT_ARCHS = ["qwen2-7b", "gemma-2b", "nemotron-4-15b", "deepseek-v3-671b",
              "deepseek-moe-16b", "mamba2-1.3b", "recurrentgemma-9b",
              "moonshot-v1-16b-a3b"]


@pytest.mark.parametrize("arch", TEXT_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                         .astype(np.int32))
    batch = {"tokens": tokens, "targets": tokens}

    full_logits, _, _ = jax.jit(model.forward)(params, batch)

    caches = model.init_cache(b, s + 2)
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
    dec_logits = []
    for t in range(s):
        logits, caches = step(params, caches, tokens[:, t:t + 1])
        dec_logits.append(logits[:, 0])
    dec = np.stack([np.asarray(l, np.float32) for l in dec_logits], axis=1)
    ref = np.asarray(full_logits, np.float32)

    # compare softmax distributions (logits can differ by tiny numerics
    # amplified through the unembed; probabilities are the contract)
    p_ref = jax.nn.softmax(ref, axis=-1)
    p_dec = jax.nn.softmax(dec, axis=-1)
    np.testing.assert_allclose(np.asarray(p_dec), np.asarray(p_ref),
                               rtol=2e-2, atol=2e-3)


def test_decode_matches_forward_sliding_window():
    """Rolling-window decode == windowed forward (long_500k mode)."""
    cfg = dataclasses.replace(get_config("qwen2-7b", reduced=True),
                              sliding_window=6)
    model = make_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    b, s = 1, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                         .astype(np.int32))
    full_logits, _, _ = jax.jit(model.forward)(
        params, {"tokens": tokens, "targets": tokens})

    caches = model.init_cache(b, s, rolling=True)
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, rolling=True))
    dec_logits = []
    for t in range(s):
        logits, caches = step(params, caches, tokens[:, t:t + 1])
        dec_logits.append(logits[:, 0])
    dec = np.stack([np.asarray(l, np.float32) for l in dec_logits], axis=1)
    p_ref = jax.nn.softmax(np.asarray(full_logits, np.float32), axis=-1)
    p_dec = jax.nn.softmax(dec, axis=-1)
    np.testing.assert_allclose(p_dec, p_ref, rtol=2e-2, atol=2e-3)


def test_encdec_decode_runs_against_memory():
    """seamless: decoder decode with precomputed cross-attention memory."""
    cfg = get_config("seamless-m4t-medium", reduced=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    b, s_enc = 2, 16
    frames = jnp.asarray(rng.normal(size=(b, s_enc, cfg.d_model))
                         .astype(np.float32))
    memory = jax.jit(model.encode)(params, frames)
    assert memory.shape == (b, s_enc, cfg.d_model)

    caches = model.init_cache(b, 8)
    # fill the cross-attention k/v from the encoder memory
    hd = cfg.resolved_head_dim
    dec_p = params["stack"]["dec"]

    def fill(layer_p):
        k = (memory @ layer_p["cross"]["k"]).reshape(
            b, s_enc, cfg.num_kv_heads, hd)
        v = (memory @ layer_p["cross"]["v"]).reshape(
            b, s_enc, cfg.num_kv_heads, hd)
        return k, v

    ks, vs = jax.vmap(fill)(dec_p)          # (L, B, S_enc, Hkv, hd)
    caches["dec"]["cross_k"] = ks
    caches["dec"]["cross_v"] = vs

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, caches = step(params, caches, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
