"""Layerwise (blockwise) ADMM on transformer stacks — the paper's technique
generalized beyond GCN (DESIGN.md §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.layerwise import LayerwiseADMMTrainer
from repro.core.subproblems import ADMMConfig


def _batch(cfg, b=4, s=32, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)),
    }


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma-2b", "mamba2-1.3b"])
def test_layerwise_admm_decreases_ce(arch):
    cfg = get_config(arch, reduced=True)
    tr = LayerwiseADMMTrainer(cfg, ADMMConfig(nu=1e-2, rho=1e-2))
    batch = _batch(cfg)
    state, z0 = tr.init(jax.random.key(0), batch)
    ce0, _ = tr.metrics(state, z0, batch["targets"])
    it = jax.jit(lambda s: tr.iteration(s, z0, batch["targets"]))
    for _ in range(6):
        state = it(state)
    ce, res = tr.metrics(state, z0, batch["targets"])
    assert float(ce) < 0.7 * float(ce0), (arch, float(ce0), float(ce))
    assert np.isfinite(float(res))


@pytest.mark.slow
def test_layerwise_admm_moe():
    cfg = get_config("deepseek-moe-16b", reduced=True)
    tr = LayerwiseADMMTrainer(cfg, ADMMConfig(nu=1e-2, rho=1e-2))
    batch = _batch(cfg)
    state, z0 = tr.init(jax.random.key(0), batch)
    ce0, _ = tr.metrics(state, z0, batch["targets"])
    it = jax.jit(lambda s: tr.iteration(s, z0, batch["targets"]))
    for _ in range(5):
        state = it(state)
    ce, _ = tr.metrics(state, z0, batch["targets"])
    assert float(ce) < float(ce0)


def test_layerwise_admm_init_satisfies_constraints():
    """Z init from the forward pass => residual ~0 (as in the GCN core)."""
    cfg = get_config("gemma-2b", reduced=True)
    tr = LayerwiseADMMTrainer(cfg, ADMMConfig())
    batch = _batch(cfg)
    state, z0 = tr.init(jax.random.key(0), batch)
    _, res = tr.metrics(state, z0, batch["targets"])
    assert float(res) < 1e-4


def test_layerwise_admm_sharded_runs():
    """Layer axis over 'model', batch over 'data' — the ADMM-as-sharding
    mapping lowers and runs on a host mesh."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 host devices")
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices()[:4])
    cfg = get_config("qwen2-7b", reduced=True)
    tr = LayerwiseADMMTrainer(cfg, ADMMConfig(nu=1e-2, rho=1e-2), mesh=mesh)
    batch = _batch(cfg)
    with mesh:
        state, z0 = tr.init(jax.random.key(0), batch)
        ce0, _ = tr.metrics(state, z0, batch["targets"])
        it = jax.jit(lambda s: tr.iteration(s, z0, batch["targets"]))
        for _ in range(4):
            state = it(state)
        ce, _ = tr.metrics(state, z0, batch["targets"])
    assert float(ce) < float(ce0)
