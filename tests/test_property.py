"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import graph, messages
from repro.core.subproblems import ADMMConfig, backtracking_step

SETTINGS = {"max_examples": 25, "deadline": None}


def _random_graph(n, extra_edges, seed):
    rng = np.random.default_rng(seed)
    # spanning-ish chain + random extras => connected-ish, no self loops
    chain = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    extra = rng.integers(0, n, size=(extra_edges, 2))
    extra = extra[extra[:, 0] != extra[:, 1]]
    return np.unique(np.sort(np.concatenate([chain, extra]), axis=1), axis=0)


@given(n=st.integers(8, 60), extra=st.integers(0, 120),
       seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_normalized_adjacency_spectral_bound(n, extra, seed):
    """Eigenvalues of Ã = (D+I)^-1/2 (A+I) (D+I)^-1/2 lie in [-1, 1]."""
    edges = _random_graph(n, extra, seed)
    a = graph.normalized_adjacency(n, edges.astype(np.int32))
    eig = np.linalg.eigvalsh(a)
    assert eig.min() >= -1.0 - 1e-4 and eig.max() <= 1.0 + 1e-4


@given(n=st.integers(12, 60), extra=st.integers(0, 100),
       m=st.integers(2, 5), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_partition_is_a_partition(n, extra, m, seed):
    edges = _random_graph(n, extra, seed).astype(np.int32)
    part = graph.partition_graph(n, edges, m, seed=seed)
    assert part.shape == (n,)
    assert part.min() >= 0 and part.max() < m
    sizes = np.bincount(part, minlength=m)
    assert sizes.max() <= int(np.ceil(n / m)) + 1   # balance cap


def _gnarly_graph(n, extra, iso, loops, seed):
    """Random graph with the contract's corner cases baked in: ``iso``
    trailing isolated nodes (no incident edges) and ``loops`` self-loop
    edges (which every partitioner must ignore, not crash on)."""
    core = max(n - iso, 2)
    edges = _random_graph(core, extra, seed).astype(np.int32)
    if loops:
        rng = np.random.default_rng(seed + 1)
        sl = rng.integers(0, n, size=loops).astype(np.int32)
        edges = np.concatenate([edges, np.stack([sl, sl], axis=1)])
    return edges


@given(n=st.integers(12, 60), extra=st.integers(0, 100),
       iso=st.integers(0, 6), loops=st.integers(0, 4),
       m=st.integers(2, 5), seed=st.integers(0, 5),
       method=st.sampled_from(["bfs_kl", "multilevel"]))
@settings(**SETTINGS)
def test_partitioner_contract(n, extra, iso, loops, m, seed, method):
    """Both partition_graph methods share one contract, including on
    graphs with isolated nodes and self-loops: every node assigned exactly
    once to a valid part, sizes within the balance bound, and bit-identical
    output for a fixed seed (determinism)."""
    edges = _gnarly_graph(n, extra, iso, loops, seed)
    part = graph.partition_graph(n, edges, m, seed=seed, method=method)
    assert part.shape == (n,) and part.dtype == np.int32
    assert part.min() >= 0 and part.max() < m       # every node assigned
    sizes = np.bincount(part, minlength=m)
    cap = int(np.ceil(n / m))
    slack = 1 if method == "bfs_kl" else 0          # multilevel: strict cap
    assert sizes.max() <= cap + slack, (method, sizes, cap)
    again = graph.partition_graph(n, edges, m, seed=seed, method=method)
    np.testing.assert_array_equal(part, again)      # determinism


@given(n=st.integers(8, 40), extra=st.integers(0, 60),
       seed=st.integers(0, 5),
       method=st.sampled_from(["bfs_kl", "multilevel"]))
@settings(**SETTINGS)
def test_partitioner_single_community(n, extra, seed, method):
    """num_parts=1 must be the trivial partition for both methods —
    contract parity at the degenerate end."""
    edges = _random_graph(n, extra, seed).astype(np.int32)
    part = graph.partition_graph(n, edges, 1, seed=seed, method=method)
    assert np.array_equal(part, np.zeros(n, dtype=np.int32))


@given(n=st.integers(12, 48), extra=st.integers(5, 80),
       m=st.integers(2, 4), c=st.integers(1, 9), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_blocked_spmm_equals_dense(n, extra, m, c, seed):
    """Community-blocked aggregation == dense Ã @ X for any partition."""
    edges = _random_graph(n, extra, seed).astype(np.int32)
    part = graph.partition_graph(n, edges, m, seed=seed)
    layout = graph.build_community_layout(n, edges, part)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, c)).astype(np.float32)
    a = graph.normalized_adjacency(n, edges)
    out_blocks = np.einsum("mrip,rpc->mic", layout.a_blocks, layout.pack(x))
    np.testing.assert_allclose(layout.unpack(out_blocks), a @ x,
                               rtol=2e-4, atol=2e-4)


@given(n=st.integers(16, 48), extra=st.integers(5, 60),
       m=st.integers(2, 4), c=st.integers(1, 8), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_masked_aggregation_equals_dense(n, extra, m, c, seed):
    """Masked community_spmm (einsum + ref oracle) == the dense reduction:
    for a real layout absent blocks are exactly zero, so restricting the
    sum to r ∈ N_m loses nothing."""
    from repro.kernels import ref
    edges = _random_graph(n, extra, seed).astype(np.int32)
    part = graph.partition_graph(n, edges, m, seed=seed)
    layout = graph.build_community_layout(n, edges, part)
    rng = np.random.default_rng(seed)
    z = layout.pack(rng.normal(size=(n, c)).astype(np.float32))
    dense = np.einsum("mrip,rpc->mic", layout.a_blocks, z)
    nbr = layout.neighbor_mask.astype(np.float32)
    masked_einsum = np.einsum("mrip,rpc->mic",
                              layout.a_blocks * nbr[:, :, None, None], z)
    np.testing.assert_allclose(masked_einsum, dense, rtol=1e-5, atol=1e-5)
    for me in range(layout.num_parts):
        out = ref.community_spmm_ref(jnp.asarray(layout.a_blocks[me]),
                                     jnp.asarray(z),
                                     jnp.asarray(nbr[me]))
        np.testing.assert_allclose(np.asarray(out), dense[me],
                                   rtol=1e-4, atol=1e-4)


@given(n=st.integers(16, 48), extra=st.integers(5, 60),
       m=st.integers(2, 4), c=st.integers(1, 6), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_sparse_layout_roundtrip(n, extra, m, c, seed):
    """BlockCSR reconstructs the dense blocks, its spmm matches the dense
    aggregation, and pack/unpack round-trips node arrays."""
    edges = _random_graph(n, extra, seed).astype(np.int32)
    part = graph.partition_graph(n, edges, m, seed=seed)
    layout = graph.build_community_layout(n, edges, part, compressed=True)
    csr = layout.compress()
    assert csr.nnz == layout.nnz_blocks <= layout.num_parts ** 2
    np.testing.assert_allclose(csr.to_dense(), layout.a_blocks,
                               rtol=0, atol=0)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, c)).astype(np.float32)
    z = layout.pack(x)
    dense = np.einsum("mrip,rpc->mic", layout.a_blocks, z)
    np.testing.assert_allclose(csr.spmm(z), dense, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(layout.unpack(z), x, rtol=0, atol=0)


@given(sizes=st.lists(st.integers(0, 40), min_size=2, max_size=8),
       extra=st.integers(0, 60), c=st.integers(1, 5),
       seed=st.integers(0, 5),
       pad_mode=st.sampled_from(["global", "bucketed"]))
@settings(**SETTINGS)
def test_ragged_blockify_roundtrip(sizes, extra, c, seed, pad_mode):
    """Ragged blockify/unblockify round-trips node arrays for ANY community
    size distribution — skewed, empty and singleton communities included —
    under both pad schemes, with bucketed row counts always covering the
    true sizes within the packed envelope."""
    if sum(sizes) < 2:
        sizes = sizes + [2]
    m = len(sizes)
    rng = np.random.default_rng(seed)
    part = np.repeat(np.arange(m), sizes).astype(np.int32)
    rng.shuffle(part)                       # arbitrary node order
    n = len(part)
    edges = _random_graph(n, extra, seed).astype(np.int32)
    layout = graph.build_community_layout(n, edges, part, num_parts=m,
                                          pad_mode=pad_mode)
    assert layout.num_parts == m            # empty communities kept
    np.testing.assert_array_equal(layout.sizes,
                                  np.bincount(part, minlength=m))
    counts = layout.eff_row_counts()
    assert (counts >= layout.sizes).all()
    assert (counts <= layout.n_pad).all()
    assert int(counts.sum()) <= m * layout.n_pad
    x = rng.normal(size=(n, c)).astype(np.float32)
    np.testing.assert_array_equal(layout.unblockify(layout.blockify(x)), x)
    np.testing.assert_array_equal(layout.unpack(layout.pack(x)), x)
    # ragged rows save exactly the bucket-vs-global pad delta
    assert layout.blockify(x).shape[0] == int(counts.sum())


@given(lanes=st.integers(1, 4), n_shards=st.integers(1, 4),
       size_max=st.integers(1, 40), extra=st.integers(0, 60),
       c=st.integers(1, 5), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_packed_device_state_roundtrip(lanes, n_shards, size_max, extra,
                                       c, seed):
    """pack_state/unpack_state round-trips any blocked state tensor that
    honours the zero-outside-counts contract — bitwise, for ANY community
    size distribution (empty and singleton communities included) and any
    divisor shard count — and the packed plane geometry always sits
    between the Σ-bucket-rows floor and the strided M·n_pad ceiling."""
    m = lanes * n_shards
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, size_max + 1, size=m)
    if sizes.sum() < 2:
        sizes[0] = 2
    part = np.repeat(np.arange(m), sizes).astype(np.int32)
    rng.shuffle(part)
    n = len(part)
    edges = _random_graph(n, extra, seed).astype(np.int32)
    layout = graph.build_community_layout(n, edges, part, num_parts=m,
                                          pad_mode="bucketed")
    dl = layout.device_layout(n_shards)
    assert dl.plane_rows % 8 == 0
    assert dl.total_rows == n_shards * dl.plane_rows
    assert dl.true_rows == int(layout.eff_row_counts().sum())
    assert dl.true_rows <= dl.total_rows <= m * layout.n_pad
    np.testing.assert_array_equal(dl.row_counts, layout.eff_row_counts())
    x = rng.normal(size=(n, c)).astype(np.float32)
    blocked = layout.pack(x)                   # zero outside true rows
    packed = dl.pack_state(blocked)
    assert packed.shape == (dl.total_rows, c)
    np.testing.assert_array_equal(dl.unpack_state(packed), blocked)
    # packing the unpacked plane is also lossless: every live plane row
    # appears exactly once in the blocked stack
    np.testing.assert_array_equal(dl.pack_state(dl.unpack_state(packed)),
                                  packed)
    np.testing.assert_array_equal(layout.unpack(dl.unpack_state(packed)), x)


@given(seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_backtracking_never_increases_objective(seed):
    """Quadratic-approx step with accepted τ never increases a convex obj."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(12, 12)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(12, 3)).astype(np.float32))

    def obj(x):
        r = a @ x - b
        return jnp.vdot(r, r).real

    x0 = jnp.asarray(rng.normal(size=(12, 3)).astype(np.float32))
    x1, tau = backtracking_step(obj, x0, jnp.asarray(1.0), ADMMConfig())
    assert float(obj(x1)) <= float(obj(x0)) * (1 + 1e-5)
    assert float(tau) > 0


@given(m=st.integers(2, 4), n_pad=st.sampled_from([16, 24]),
       c=st.integers(2, 8), seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_relay_identity(m, n_pad, c, seed):
    """q_r − Ã_{r,me} Z_me W == Σ_{r'≠me} Ã_{r,r'} Z_r' W (eq. 4) for random
    symmetric block matrices."""
    rng = np.random.default_rng(seed)
    blocks = rng.normal(size=(m, m, n_pad, n_pad)).astype(np.float32)
    blocks = (blocks + blocks.transpose(1, 0, 3, 2)) / 2   # symmetric Ã
    z = jnp.asarray(rng.normal(size=(m, n_pad, c)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(c, c)).astype(np.float32))
    a = jnp.asarray(blocks)
    me = 0
    q_all = jnp.stack([messages.relay_aggregate(a[r], z, w)
                       for r in range(m)])
    s2 = messages.second_order_from_relay(q_all, a[me], z[me], w)
    for r in range(m):
        expect = sum(blocks[r, rp] @ np.asarray(z[rp])
                     for rp in range(m) if rp != me) @ np.asarray(w)
        np.testing.assert_allclose(np.asarray(s2[r]), expect,
                                   rtol=3e-3, atol=3e-3)


@given(b=st.integers(1, 3), s=st.sampled_from([16, 32]),
       seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_rope_preserves_norm(b, s, seed):
    """Rotary embedding is an isometry per (head, position)."""
    from repro.models.layers import apply_rope
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, 2, 16)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=2e-4, atol=2e-4)


@given(t=st.sampled_from([32, 64]), e=st.sampled_from([4, 8]),
       k=st.integers(1, 3), seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_moe_rank_unique_within_expert(t, e, k, seed):
    """The sort-based dispatch rank is a bijection into capacity slots:
    kept (token, slot) pairs of one expert get distinct ranks."""
    rng = np.random.default_rng(seed)
    flat_expert = jnp.asarray(rng.integers(0, e, t * k).astype(np.int32))
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_experts = flat_expert[sort_idx]
    idx = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_experts[1:] != sorted_experts[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    rank_sorted = idx - group_start
    rank = np.zeros(t * k, np.int32)
    rank[np.asarray(sort_idx)] = np.asarray(rank_sorted)
    for ex in range(e):
        ranks = rank[np.asarray(flat_expert) == ex]
        assert len(set(ranks.tolist())) == len(ranks)
        if len(ranks):
            assert sorted(ranks.tolist()) == list(range(len(ranks)))


# ---------------------------------------------------------------------------
# serving cache / batcher / engine invariants (repro.serve)
# ---------------------------------------------------------------------------

_CACHE_OPS = st.lists(
    st.tuples(st.sampled_from(["get", "put", "invalidate"]),
              st.integers(0, 11)),
    min_size=1, max_size=120)


@given(capacity=st.integers(1, 6), ops=_CACHE_OPS)
@settings(**SETTINGS)
def test_lru_cache_matches_ordered_dict_model(capacity, ops):
    """Plain-LRU admission is exactly an OrderedDict-with-cap: same keys,
    same eviction order, after any op sequence."""
    from collections import OrderedDict

    from repro.serve import LRUCache
    c = LRUCache(capacity, admission="lru")
    model: OrderedDict = OrderedDict()
    for op, k in ops:
        if op == "get":
            want = model.get(k)
            if k in model:
                model.move_to_end(k)
            assert c.get(k) == want
        elif op == "put":
            assert c.put(k, k)      # plain LRU admits everything
            if k in model:
                model.move_to_end(k)
            model[k] = k
            if len(model) > capacity:
                model.popitem(last=False)
        else:
            assert c.invalidate(k) == (k in model)
            model.pop(k, None)
        assert c.keys() == list(model)
        assert len(c) <= capacity


@given(capacity=st.integers(0, 6), ops=_CACHE_OPS)
@settings(**SETTINGS)
def test_zipf_admission_invariants(capacity, ops):
    """Zipf admission: size never exceeds capacity, a rejected put leaves
    the cache untouched, and an eviction never swaps a strictly hotter
    victim for a colder candidate (the sketch's invariant)."""
    from repro.serve import LRUCache
    c = LRUCache(capacity, admission="zipf")
    for op, k in ops:
        if op == "get":
            got = c.get(k)
            assert (got is not None) == (k in c)
        elif op == "put":
            before = c.keys()
            full = len(c) >= capacity and k not in c
            victim = before[0] if before else None
            est = c._sketch.estimate
            admitted = c.put(k, k)
            if admitted:
                assert k in c
                if full and capacity:
                    # the displaced victim was not strictly hotter
                    assert est(victim) <= est(k)
            else:
                assert c.keys() == before and k not in c
        else:
            c.invalidate(k)
            assert k not in c
        assert len(c) <= capacity


@given(n=st.integers(8, 200), reqs=st.integers(1, 64),
       m=st.integers(1, 7), seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_batcher_coalesce_is_a_partition(n, reqs, m, seed):
    """coalesce() partitions the request vector: positions are a disjoint
    cover, every bucket is on the pad ladder, rows match the node tables."""
    from repro.serve import RequestBatcher
    rng = np.random.default_rng(seed)
    node_comm = rng.integers(0, m, n).astype(np.int32)
    node_row = rng.integers(0, 32, n).astype(np.int32)
    bat = RequestBatcher(node_comm, node_row, max_batch=64)
    ids = rng.integers(0, n, reqs)
    batches = bat.coalesce(ids)
    seen = np.concatenate([b.positions for b in batches])
    assert sorted(seen.tolist()) == list(range(reqs))
    assert [b.comm for b in batches] == sorted(b.comm for b in batches)
    for b in batches:
        assert b.bucket in bat.ladder and b.bucket >= b.count
        np.testing.assert_array_equal(node_comm[ids[b.positions]], b.comm)
        np.testing.assert_array_equal(b.rows[:b.count],
                                      node_row[ids[b.positions]])


@pytest.fixture(scope="module")
def _property_server():
    from repro.core import gcn
    from repro.serve import CommunityServer
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=4, nodes_per_part=10, attach=1, seed=0, feat_dim=4,
        size_skew=0.8)
    cfg = gcn.GCNConfig(layer_dims=(4, 4, g.num_classes))
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed", num_parts=4)
    ws = gcn.init_weights(cfg, jax.random.key(0))
    return g, cfg, layout, ws, CommunityServer(cfg, layout, ws, g.features)


@given(ids=st.lists(st.integers(0, 39), min_size=1, max_size=24))
@settings(**SETTINGS)
def test_serve_hit_after_miss_is_bitwise(_property_server, ids):
    """Any request vector served twice is bitwise-identical: the cached
    block IS the block the miss computed."""
    *_, srv = _property_server
    arr = np.asarray(ids)
    first = srv.serve(arr)
    np.testing.assert_array_equal(first, srv.serve(arr))


@given(node=st.integers(0, 39), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_serve_invalidation_parity_with_fresh_engine(_property_server,
                                                     node, seed):
    """After an arbitrary single-node feature update, the invalidated
    engine serves bitwise what a fresh engine on the updated features
    serves — invalidation dropped everything stale and nothing it needs."""
    from repro.serve import CommunityServer
    g, cfg, layout, ws, srv = _property_server
    ids = np.arange(g.num_nodes)
    srv.serve(ids)
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(1, cfg.layer_dims[0])).astype(np.float32)
    srv.update_features([node], feats)
    updated = np.asarray(srv.z0_plane)[srv._node_plane_row]
    fresh = CommunityServer(cfg, layout, ws, updated)
    np.testing.assert_array_equal(srv.serve(ids), fresh.serve(ids))
