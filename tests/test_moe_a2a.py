"""Expert-parallel all-to-all MoE dispatch (§Perf pair-2 iterations 4-7):
bit-equivalence with the portable path on a real host mesh, and correct
gating (portable path inside manual regions / without hints)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_lib
from repro.sharding.hints import sharding_hints
from repro.util.compat import make_mesh


@pytest.fixture(scope="module")
def setup():
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 host devices")
    cfg = get_config("deepseek-moe-16b", reduced=True)
    p = moe_lib.init_moe(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model))
                    .astype(np.float32) * 0.5)
    mesh = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    return cfg, p, x, mesh


def test_a2a_matches_portable(setup):
    cfg, p, x, mesh = setup
    base, aux_b = jax.jit(lambda p, x: moe_lib.apply_moe(cfg, p, x))(p, x)
    with mesh, sharding_hints(mesh, moe_a2a=True):
        a2a, aux_a = jax.jit(lambda p, x: moe_lib.apply_moe(cfg, p, x))(p, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(a2a),
                               rtol=1e-5, atol=1e-5)
    # aux differs only through per-shard capacity rounding
    assert abs(float(aux_b) - float(aux_a)) < 1e-4


def test_a2a_gated_off_without_hints(setup):
    cfg, p, x, mesh = setup
    # no hints context: portable path (no shard_map in the jaxpr)
    jaxpr = jax.make_jaxpr(lambda p, x: moe_lib.apply_moe(cfg, p, x))(p, x)
    assert "shard_map" not in str(jaxpr)


def test_a2a_gated_off_inside_manual_region(setup):
    """Inside an enclosing shard_map (deferred train step) the a2a path
    must defer to the portable dispatch instead of nesting shard_maps."""
    from jax.sharding import PartitionSpec as P
    from repro.util import shard_map
    cfg, p, x, mesh = setup

    def body(xs):
        out, _ = moe_lib.apply_moe(cfg, p, xs)
        return out

    with mesh, sharding_hints(mesh, moe_a2a=True):
        fn = shard_map(body, mesh=mesh, in_specs=P("data", None, None),
                       out_specs=P("data", None, None), check_rep=False,
                       axis_names=("data",))
        out = jax.jit(fn)(x)          # would raise on nested manual axes
    assert np.isfinite(np.asarray(out)).all()


def test_a2a_train_step_deferred_composes(setup):
    """End-to-end: the deferred train step on an MoE arch with hints+a2a
    enabled lowers and runs (a2a gated off inside, hints filtered)."""
    cfg, _, _, mesh = setup
    from repro.models.build import make_model
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    opt_state = model.init_optimizer().init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))
                                   .astype(np.int32)),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))
                                    .astype(np.int32))}
    with mesh, sharding_hints(mesh, moe_a2a=True):
        step = jax.jit(lambda p, o, b: model.train_step_deferred(
            mesh, p, o, b))
        params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
