"""Fused aggregation→Z-update: the single-pass Pallas kernel, its
reassociated oracle, the TrainerConfig plumbing, and the
memory/fused-no-intermediate analysis rule.

The contract under test: ``fused=True`` changes WHERE the aggregated
``(k, n_pad, C)`` stack lives (VMEM scratch / never materialised), never
what a Z-update target computes.  The fused kernel's aggregate
accumulation is the packed kernel's bitwise; the closing GEMM
reassociates ``(A·Z)·W`` to ``A·(Z·W)``, so fused-vs-unfused parity is
per-iteration dot-order tolerance (≤1e-6 at GCN widths).  On one shard
the packed wire is off and ``fused=True`` is inert — the trainer stays
bitwise-identical to unfused.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.registry import AnalysisContext
from repro.analysis.rules.memory import (fused_agg_handoffs,
                                         fused_no_intermediate)
from repro.analysis.rules.pallas import (check_kernel_bounds,
                                         check_kernel_vmem)
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig
from repro.kernels import ops, ref
from repro.kernels.community_spmm import (community_spmm_ell_fused,
                                          ell_fused_spec)
from repro.util.compat import make_mesh


# ---------------------------------------------------------------------------
# the fused kernel vs its oracles
# ---------------------------------------------------------------------------

def _packed_inputs(k, max_deg, n_pad, c_in, c_out, seed=0):
    """Synthetic packed receive plane honouring the layout contract:
    8-aligned slot offsets, bucket row counts in multiples of 8, slots
    packed back to back."""
    rng = np.random.default_rng(seed)
    n_slots = k + 2
    counts = 8 * rng.integers(1, n_pad // 8 + 1, size=n_slots)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int32)
    plane_rows = int(counts.sum())
    slot = rng.integers(0, n_slots, size=(k, max_deg))
    ell_offsets = offsets[slot].astype(np.int32)
    nbr_counts = counts[slot].astype(np.int32)
    mask = np.zeros((k, max_deg), np.int32)
    for r in range(k):
        mask[r, : 1 + r % max_deg] = 1
    row_counts = (8 * rng.integers(1, n_pad // 8 + 1,
                                   size=k)).astype(np.int32)
    blocks = rng.normal(size=(k, max_deg, n_pad, n_pad)).astype(np.float32)
    # zero-outside-counts contract: adjacency rows past the lane's count
    # and columns past the neighbour's count are zero in packed tensors
    lane = np.arange(n_pad)
    blocks *= (lane[None, None, :, None] < row_counts[:, None, None, None])
    blocks *= (lane[None, None, None, :] < nbr_counts[:, :, None, None])
    z_plane = rng.normal(size=(plane_rows, c_in)).astype(np.float32)
    w = rng.normal(size=(c_in, c_out)).astype(np.float32)
    return tuple(jnp.asarray(x) for x in
                 (blocks, ell_offsets, mask, z_plane, w, row_counts,
                  nbr_counts))


@pytest.mark.parametrize("k,max_deg,n_pad,c_in,c_out", [
    (2, 3, 32, 8, 8),       # square W (the hidden-layer target shape)
    (3, 2, 16, 8, 4),       # narrowing W (the output-layer shape)
    (2, 1, 64, 16, 8),      # single-neighbour rows
    (4, 4, 24, 4, 12),      # widening W, ragged fan-in
])
def test_fused_kernel_matches_oracles(k, max_deg, n_pad, c_in, c_out):
    """Interpret-mode fused kernel vs the reassociated einsum oracle vs
    the two-step packed-aggregate→GEMM reference."""
    args = _packed_inputs(k, max_deg, n_pad, c_in, c_out)
    blocks, off, mask, z_plane, w, rows, nbrs = args
    out = community_spmm_ell_fused(*args, interpret=True)
    oracle = ref.community_spmm_ell_fused_einsum(*args)
    agg = ref.community_spmm_ell_packed_einsum(blocks, off, mask, z_plane,
                                               rows, nbrs)
    two_step = agg @ w
    assert out.shape == (k, n_pad, c_out)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    # reassociation tolerance, not bitwise — the fused acceptance bound
    np.testing.assert_allclose(np.asarray(out), np.asarray(two_step),
                               rtol=1e-4, atol=1e-4)


def test_fused_kernel_respects_masks_and_row_counts():
    """Masked slots must not contribute and rows past a lane's count must
    stay zero — the same guards the packed kernel carries, now ahead of
    the in-kernel GEMM."""
    args = _packed_inputs(3, 3, 32, 8, 8, seed=5)
    blocks, off, mask, z_plane, w, rows, nbrs = args
    out = np.asarray(community_spmm_ell_fused(*args, interpret=True))
    lane = np.arange(32)
    for m in range(3):
        dead = out[m, lane >= int(rows[m])]
        np.testing.assert_array_equal(dead, np.zeros_like(dead))
    full = community_spmm_ell_fused(blocks, off, jnp.ones_like(mask),
                                    z_plane, w, rows, nbrs, interpret=True)
    assert np.abs(out - np.asarray(full)).max() > 1e-3


def test_fused_dispatch_cpu_is_the_oracle():
    """Off-TPU the ops wrapper dispatches to the reassociated einsum
    oracle at trace time — bitwise, which is what keeps the CPU-compiled
    fused step free of the aggregated intermediate."""
    args = _packed_inputs(2, 2, 16, 8, 4, seed=2)
    np.testing.assert_array_equal(
        np.asarray(ops.community_spmm_ell_fused(*args)),
        np.asarray(ref.community_spmm_ell_fused_einsum(*args)))


def test_fused_spec_passes_pallas_checks():
    """The shipped fused spec is clean under the bounds and VMEM rules
    with realistic packed scalars (benchmark widths)."""
    k, max_deg, n_pad, c = 2, 3, 256, 256
    plane_rows = 1024
    spec = ell_fused_spec(k, max_deg, n_pad, c, c, plane_rows)
    scalars = {"ell_offsets8": np.zeros((k, max_deg), np.int32),
               "ell_mask": np.ones((k, max_deg), np.int32),
               "row_counts": np.full((k,), n_pad, np.int32),
               "nbr_counts": np.full((k, max_deg), n_pad, np.int32)}
    assert not check_kernel_bounds(spec, scalars)
    assert not check_kernel_vmem(spec)
    # an offset table pointing past the plane must be flagged
    bad = dict(scalars, ell_offsets8=np.full((k, max_deg),
                                             plane_rows // 8, np.int32))
    findings = check_kernel_bounds(spec, bad)
    assert findings and findings[0].rule == "pallas/index-bounds"


# ---------------------------------------------------------------------------
# TrainerConfig plumbing
# ---------------------------------------------------------------------------

def test_trainer_config_fused_requires_packed():
    with pytest.raises(ValueError, match="fused=True requires packed"):
        TrainerConfig(compressed=True, transport="p2p",
                      pad_mode="bucketed", fused=True)
    cfg = TrainerConfig.packed(fused=True)
    assert cfg.fused and cfg.packed
    assert TrainerConfig.packed().fused is False


def _trainer(g, part, mesh, **kw):
    cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    m = int(part.max()) + 1
    return ParallelADMMTrainer(cfg, admm, g, num_parts=m, seed=0,
                               part=part, mesh=mesh,
                               config=TrainerConfig.packed(**kw))


def test_fused_one_shard_is_bitwise_inert():
    """On one shard there is no packed wire plane, the blocked body runs,
    and fused=True must change nothing — bitwise."""
    g, part = graph.synthetic_powerlaw_communities(
        num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
        size_skew=0.8)
    mesh = make_mesh((1,), (AXIS,))
    ref_tr = _trainer(g, part, mesh)
    fu_tr = _trainer(g, part, mesh, fused=True)
    for _ in range(3):
        ref_tr.step()
        fu_tr.step()
    for zr, zf in zip(ref_tr.state.zs, fu_tr.state.zs):
        np.testing.assert_array_equal(np.asarray(zr), np.asarray(zf))
    np.testing.assert_array_equal(np.asarray(ref_tr.state.u),
                                  np.asarray(fu_tr.state.u))
    for wr, wf in zip(ref_tr.state.weights, fu_tr.state.weights):
        np.testing.assert_array_equal(np.asarray(wr), np.asarray(wf))


# ---------------------------------------------------------------------------
# the memory/fused-no-intermediate rule
# ---------------------------------------------------------------------------

N_PAD = 16


def _toy_ops(seed=0):
    rng = np.random.default_rng(seed)
    blocks = jnp.asarray(rng.normal(size=(1, 2, N_PAD, N_PAD))
                         .astype(np.float32))
    z = jnp.asarray(rng.normal(size=(1, 2, N_PAD, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    return blocks, z, w


def test_fused_handoff_walk_counts_agg_to_dot():
    blocks, z, w = _toy_ops()

    def unfused(blocks, z, w):
        agg = jnp.einsum("mdip,mdpc->mic", blocks, z)   # (1, n_pad, 8)
        return agg @ w

    def fused(blocks, z, w):
        return jnp.einsum("mdip,mdpc->mic", blocks, z @ w)

    jx_u = jax.make_jaxpr(unfused)(blocks, z, w)
    jx_f = jax.make_jaxpr(fused)(blocks, z, w)
    assert len(fused_agg_handoffs(jx_u, N_PAD)) == 1
    assert len(fused_agg_handoffs(jx_f, N_PAD)) == 0


def test_fused_handoff_walk_follows_partial_sums_only():
    """Taint crosses the overlap path's add-of-partials into the dot, but
    does NOT leak through activations into downstream dots (the fused
    sites' own outputs feed the solvers legitimately)."""
    blocks, z, w = _toy_ops()

    def overlap_unfused(blocks, z, w):
        a = jnp.einsum("mdip,mdpc->mic", blocks, z)
        b = jnp.einsum("mdip,mdpc->mic", blocks, 2.0 * z)
        return (a + b) @ w                               # one handoff

    def fused_then_consumed(blocks, z, w):
        out = jnp.einsum("mdip,mdpc->mic", blocks, z @ w)   # (1, n_pad, 4)
        act = jax.nn.relu(out)                           # carrier break
        return act @ jnp.ones((4, 3), jnp.float32)       # no handoff

    assert len(fused_agg_handoffs(
        jax.make_jaxpr(overlap_unfused)(blocks, z, w), N_PAD)) == 1
    assert len(fused_agg_handoffs(
        jax.make_jaxpr(fused_then_consumed)(blocks, z, w), N_PAD)) == 0


def test_fused_no_intermediate_rule_fires_and_stays_silent():
    blocks, z, w = _toy_ops()

    def unfused(blocks, z, w):
        return jnp.einsum("mdip,mdpc->mic", blocks, z) @ w

    def fused(blocks, z, w):
        return jnp.einsum("mdip,mdpc->mic", blocks, z @ w)

    exp = {"n_pad": N_PAD, "fused": True, "fused_max_agg_handoffs": 0}

    def run(fn, expectations):
        ctx = AnalysisContext(
            hlo_text=None, jaxpr=jax.make_jaxpr(fn)(blocks, z, w),
            expectations=expectations, config="toy")
        return list(fused_no_intermediate(ctx))

    hits = run(unfused, exp)
    assert hits and hits[0].rule == "memory/fused-no-intermediate"
    assert hits[0].details["count"] == 1
    assert not run(fused, exp)
    # the W-update allowance: one surviving aggregate per layer is blessed
    assert not run(unfused, dict(exp, fused_max_agg_handoffs=1))
    # unfused configs are out of scope
    assert not run(unfused, {"n_pad": N_PAD, "fused": False})


# ---------------------------------------------------------------------------
# 4-shard subprocess: per-iteration parity, the compiled-step proof, and
# the rule firing on the unfused program under fused expectations
# ---------------------------------------------------------------------------

_FUSED_WORKER = r"""
import numpy as np, jax
import jax.numpy as jnp
from repro import analysis
from repro.analysis.rules.memory import fused_agg_handoffs
from repro.core import gcn, graph
from repro.core.parallel import AXIS, ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig
from repro.util.compat import make_mesh

g, part = graph.synthetic_powerlaw_communities(
    num_parts=8, nodes_per_part=12, attach=1, seed=0, feat_dim=8,
    size_skew=0.8)
cfg = gcn.GCNConfig(layer_dims=(8, 8, g.num_classes))
admm = ADMMConfig(nu=1e-3, rho=1e-3)
mesh = make_mesh((4,), (AXIS,), devices=jax.devices()[:4])

def build(**kw):
    return ParallelADMMTrainer(cfg, admm, g, num_parts=8, seed=0,
                               part=part, mesh=mesh,
                               config=TrainerConfig.packed(**kw))

def delta(a, b):
    return max(
        max(float(jnp.max(jnp.abs(x - y)))
            for x, y in zip(a.weights, b.weights)),
        max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(a.zs, b.zs)),
        float(jnp.max(jnp.abs(a.u - b.u))))

# --- per-iteration W/Z/U parity from a shared state: ≤ 1e-6 ---
un = build()
fu = build(fused=True)
state = un.state
for _ in range(3):
    fu_next = fu._step(jax.tree.map(jnp.copy, state))
    state = un._step(state)
    d = delta(state, fu_next)
    assert d <= 1e-6, f"fused parity {d} above 1e-6"
print("FU_PARITY_OK")

# --- the compiled fused step passes the analysis registry, the rule
#     counts exactly the W-update floor ---
n_pad = fu.layout.n_pad
fu_h = len(fused_agg_handoffs(jax.make_jaxpr(fu._step)(fu.state), n_pad))
un_h = len(fused_agg_handoffs(jax.make_jaxpr(un._step)(un.state), n_pad))
assert fu_h == cfg.num_layers, (fu_h, cfg.num_layers)
assert un_h > fu_h, (un_h, fu_h)
waivers = (analysis.Waiver(
    "pallas/tile-alignment", "packed ELL contracts in 8-row steps",
    when={"state_packed": True}),)
rep = analysis.analyze_trainer(fu, config="p2p_fused", waivers=waivers)
assert analysis.no_findings(rep, rule="memory/fused-no-intermediate")
assert not rep.errors(), rep.summary()
print("FU_ANALYSIS_OK")

# --- the rule FIRES when the unfused program is held to the fused
#     contract (proves the proof is not vacuous) ---
from repro.analysis.registry import AnalysisContext
from repro.analysis.rules.memory import fused_no_intermediate
ctx = AnalysisContext(
    hlo_text=None, jaxpr=jax.make_jaxpr(un._step)(un.state),
    expectations={"n_pad": n_pad, "fused": True,
                  "fused_max_agg_handoffs": cfg.num_layers},
    config="unfused-held-to-fused")
hits = list(fused_no_intermediate(ctx))
assert hits and hits[0].details["count"] == un_h, hits
print("FU_RULE_FIRES_OK")

# --- overlap composes: per-group fused aggregation, same handoff floor,
#     tolerance parity against the fused non-overlap trainer ---
ov = build(fused=True, overlap=True)
ov_h = len(fused_agg_handoffs(jax.make_jaxpr(ov._step)(ov.state), n_pad))
assert ov_h == cfg.num_layers, ov_h
fu2 = build(fused=True)
for _ in range(3):
    ov.step(); fu2.step()
d = delta(ov.state, fu2.state)
assert d <= 1e-4, f"fused overlap parity {d}"
print("FU_OVERLAP_OK")
"""


def test_fused_on_4_shards():
    """The acceptance run: fused vs unfused per-iteration W/Z/U parity
    ≤1e-6 on 4 shards, the compiled fused step passes
    memory/fused-no-intermediate at the W-update floor, the rule fires on
    the unfused program under fused expectations, and overlap composes at
    the same floor."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _FUSED_WORKER],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("FU_PARITY_OK", "FU_ANALYSIS_OK", "FU_RULE_FIRES_OK",
                "FU_OVERLAP_OK"):
        assert tag in out.stdout, out.stdout
