"""Per-kernel validation: Pallas (interpret=True — executes the kernel body
on CPU) vs the pure-jnp oracle in ref.py, swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.community_spmm import community_spmm, community_spmm_ell
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    # f32 tolerance covers matmul reassociation between tiled and dense paths
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.bfloat16 \
        else {"rtol": 2e-4, "atol": 2e-4}


# ---------------------------------------------------------------------------
# community_spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n_pad,c", [(3, 64, 32), (4, 128, 256),
                                       (2, 256, 48), (5, 72, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_community_spmm_matches_ref(m, n_pad, c, dtype):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(m, n_pad, n_pad)).astype(np.float32)
    # block sparsity: zero some blocks and mask them
    mask = rng.random(m) > 0.3
    mask[0] = True
    a[~mask] = 0.0
    z = rng.normal(size=(m, n_pad, c)).astype(np.float32)
    a, z = jnp.asarray(a, dtype), jnp.asarray(z, dtype)
    maskj = jnp.asarray(mask)

    out = community_spmm(a, z, maskj, interpret=True)
    expect = ref.community_spmm_ref(a, z, maskj)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_community_spmm_skips_masked_blocks():
    """Masked blocks must not contribute even if their data is nonzero."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(3, 64, 64)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(3, 64, 16)).astype(np.float32))
    mask = jnp.asarray([True, False, True])
    out = community_spmm(a, z, mask, interpret=True)
    expect = ref.community_spmm_ref(a, z, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    # and differs from the unmasked product
    full = ref.community_spmm_ref(a, z, jnp.asarray([True] * 3))
    assert np.abs(np.asarray(out) - np.asarray(full)).max() > 1e-3


# ---------------------------------------------------------------------------
# community_spmm_ell (block-compressed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m_z,k,max_deg,n_pad,c", [
    (6, 6, 3, 64, 32),      # full layout (k == M)
    (8, 2, 4, 64, 48),      # shard slice (k < M, global indices)
    (4, 4, 1, 128, 128),    # single-neighbour rows
    (5, 5, 5, 72, 20),      # ragged: many padding lanes
])
def test_community_spmm_ell_matches_oracles(m_z, k, max_deg, n_pad, c):
    """Interpret-mode Pallas ELL kernel vs the einsum and loop oracles,
    with real max_deg padding lanes (mask 0, index 0) in the mix."""
    rng = np.random.default_rng(0)
    blocks = rng.normal(size=(k, max_deg, n_pad, n_pad)).astype(np.float32)
    idx = rng.integers(0, m_z, size=(k, max_deg)).astype(np.int32)
    # variable fan-in: row r keeps 1 + (r % max_deg) real slots
    mask = np.zeros((k, max_deg), np.float32)
    for r in range(k):
        mask[r, : 1 + r % max_deg] = 1.0
    z = rng.normal(size=(m_z, n_pad, c)).astype(np.float32)

    args = (jnp.asarray(blocks), jnp.asarray(idx), jnp.asarray(mask),
            jnp.asarray(z))
    out = community_spmm_ell(*args, interpret=True)
    expect = ref.community_spmm_ell_einsum(*args)
    loop = ref.community_spmm_ell_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(loop), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_community_spmm_ell_skips_padding_lanes():
    """Padding slots (mask 0) must not contribute even though they point at
    real z rows (index 0) and hold nonzero block data."""
    rng = np.random.default_rng(3)
    k, max_deg, n_pad, c = 3, 3, 64, 16
    blocks = jnp.asarray(rng.normal(size=(k, max_deg, n_pad, n_pad))
                         .astype(np.float32))
    idx = jnp.zeros((k, max_deg), jnp.int32)
    mask = jnp.asarray([[1, 0, 0], [1, 1, 0], [1, 1, 1]], jnp.float32)
    z = jnp.asarray(rng.normal(size=(4, n_pad, c)).astype(np.float32))

    out = community_spmm_ell(blocks, idx, mask, z, interpret=True)
    expect = ref.community_spmm_ell_einsum(blocks, idx, mask, z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    # and differs from the all-real-slot product
    full = ref.community_spmm_ell_einsum(blocks, idx,
                                         jnp.ones_like(mask), z)
    assert np.abs(np.asarray(out) - np.asarray(full)).max() > 1e-3


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,hd", [
    (2, 256, 4, 4, 64),     # MHA
    (1, 512, 8, 2, 64),     # GQA
    (2, 256, 4, 1, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, s, hq, hkv, hd, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, hq, hd)).astype(np.float32), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(2)
    b, s, h, hd = 1, 512, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=128, block_k=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(3)
    b, s, h, hd = 2, 256, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model's block_causal_attention path."""
    from repro.models.attention import block_causal_attention
    rng = np.random.default_rng(4)
    b, s, h, hd = 1, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    expect = block_causal_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 32, 2, 32, 32),
    (1, 256, 2, 64, 1, 64, 64),
    (2, 64, 8, 16, 4, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(b, s, h, p, g, n, chunk, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32), dtype)
    dt = jnp.asarray(0.5 * np.abs(rng.normal(size=(b, s, h))).astype(np.float32))
    a = -jnp.asarray(np.abs(rng.normal(size=(h,))).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32), dtype)
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32), dtype)
    y, _ = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    expect = ref.ssd_scan_ref(x.astype(jnp.float32), dt, a,
                              bm.astype(jnp.float32),
                              cm.astype(jnp.float32), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes give the same result (state relay correct)."""
    rng = np.random.default_rng(5)
    b, s, h, p, g, n = 1, 128, 2, 16, 1, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(0.3 * np.abs(rng.normal(size=(b, s, h))).astype(np.float32))
    a = -jnp.asarray(np.abs(rng.normal(size=(h,))).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32))
    y32, _ = ssd_scan(x, dt, a, bm, cm, chunk=32, interpret=True)
    y128, _ = ssd_scan(x, dt, a, bm, cm, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128),
                               rtol=2e-4, atol=2e-4)
