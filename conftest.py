"""Repo-level pytest bootstrap: make ``import repro`` work from a bare
``pytest`` invocation (the package lives under src/, no install step)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
