"""Beyond-paper benchmark: layerwise-ADMM vs Adam on a reduced transformer.

Full-batch regime (the paper's setting): same reduced arch, same fixed
batch, CE after equal wall-time budget — shows the technique transfers
from GCN to the assigned architectures.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.layerwise import LayerwiseADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.models.build import make_model


def run(arch: str = "qwen2-7b", iters: int = 8, batch_size: int = 4,
        seq: int = 32, seed: int = 0) -> dict:
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           (batch_size, seq)).astype(np.int32)),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                            (batch_size, seq)).astype(np.int32)),
    }

    # --- layerwise ADMM ---
    tr = LayerwiseADMMTrainer(cfg, ADMMConfig(nu=1e-2, rho=1e-2))
    state, z0 = tr.init(jax.random.key(seed), batch)
    it = jax.jit(lambda s: tr.iteration(s, z0, batch["targets"]))
    state = it(state)                                   # compile
    jax.block_until_ready(state.u)
    ce0, _ = tr.metrics(state, z0, batch["targets"])
    t0 = time.perf_counter()
    for _ in range(iters):
        state = it(state)
    jax.block_until_ready(state.u)
    admm_time = time.perf_counter() - t0
    admm_ce, admm_res = tr.metrics(state, z0, batch["targets"])

    # --- Adam on the same fixed batch ---
    model = make_model(cfg)
    params = model.init(jax.random.key(seed))
    opt_state = model.init_optimizer().init(params)
    step = jax.jit(model.train_step)
    params, opt_state, m = step(params, opt_state, batch)  # compile
    adam_steps = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < admm_time:
        params, opt_state, m = step(params, opt_state, batch)
        adam_steps += 1
    adam_ce = float(m["ce"])

    out = {
        "arch": arch,
        "admm_iters": iters, "admm_time_s": round(admm_time, 2),
        "admm_ce": float(admm_ce), "admm_residual": float(admm_res),
        "adam_steps_same_budget": adam_steps, "adam_ce": adam_ce,
    }
    print(f"[layerwise] {arch}: ADMM ce {float(admm_ce):.4f} "
          f"({iters} iters, {admm_time:.1f}s) vs Adam ce {adam_ce:.4f} "
          f"({adam_steps} steps, same budget)")
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
