"""Schema + regression checker for the repo-root BENCH_*.json artifacts.

CI runs ``benchmarks/run.py --quick`` (which emits the quick payloads and
calls this) so every push proves:

  * both artifacts parse and carry the fields the perf-trajectory tracking
    consumes (mode, M, byte counters, per-epoch seconds);
  * the compressed adjacency does not regress above the dense curve (small
    M may pay the tiny ELL index/mask overhead; the largest swept M must be
    strictly smaller);
  * the p2p transport's scheduled wire bytes stay below the all-gather
    volume — the wire-byte win the neighbour-only exchange exists for;
  * the multilevel partitioner strictly beats the BFS+KL stand-in on edge
    cut at M=32 (no worse max_deg / wire bytes, strict balance) and never
    cuts more than it on the trainer datasets — partition quality is the
    lever behind every wire-byte number;
  * size-aware (bucketed) padding beats the global n_pad on the seed-0
    size-skewed power-law graph at M=32: lower pad bytes, lower pad FLOPs,
    and a row-exact p2p wire that undercuts the whole-block schedule and
    stays within the uniform-graph multilevel wire (m32_ragged).

Standalone: ``PYTHONPATH=src python benchmarks/check_bench.py [--root DIR]``
Exit code 0 = all checks pass; failures raise CheckError with the path of
the offending field.
"""
from __future__ import annotations

import argparse
import json
import numbers
import pathlib


class CheckError(AssertionError):
    pass


def _require(cond: bool, where: str, msg: str) -> None:
    if not cond:
        raise CheckError(f"{where}: {msg}")


def _fields(row: dict, spec: dict, where: str) -> None:
    for key, typ in spec.items():
        _require(key in row, where, f"missing field {key!r}")
        _require(isinstance(row[key], typ), where,
                 f"{key!r} should be {typ}, got {type(row[key]).__name__}")


def check_block_sparsity(payload: dict) -> None:
    where = "BENCH_block_sparsity"
    _fields(payload, {"quick": bool, "agg_sweep": list,
                      "trainer_sweep": list}, where)
    _require(len(payload["agg_sweep"]) >= 2, where, "agg_sweep too short")
    for i, r in enumerate(payload["agg_sweep"]):
        w = f"{where}.agg_sweep[{i}]"
        _fields(r, {"M": int, "nnz": int, "coll_full_kb": numbers.Real,
                    "coll_needed_kb": numbers.Real,
                    "coll_wire_kb": numbers.Real,
                    "p2p_rounds": int}, w)
        _require(r["coll_wire_kb"] <= r["coll_needed_kb"] + 1e-9, w,
                 f"p2p wire {r['coll_wire_kb']}k above the needed volume "
                 f"{r['coll_needed_kb']}k")
        _require(r["coll_needed_kb"] <= r["coll_full_kb"] + 1e-9, w,
                 "needed volume above the all-gather volume")

    sweep = payload["trainer_sweep"]
    _require({r["mode"] for r in sweep} == {"dense", "compressed"}, where,
             "trainer_sweep must cover dense and compressed modes")
    by_m: dict[int, dict[str, int]] = {}
    for i, r in enumerate(sweep):
        w = f"{where}.trainer_sweep[{i}]"
        _fields(r, {"mode": str, "M": int, "adjacency_bytes": int,
                    "per_epoch_s": numbers.Real}, w)
        _require(r["adjacency_bytes"] > 0 and r["per_epoch_s"] > 0, w,
                 "non-positive measurement")
        by_m.setdefault(r["M"], {})[r["mode"]] = r["adjacency_bytes"]
    for m, d in sorted(by_m.items()):
        # regression guard: compressed adjacency must never sit above the
        # dense curve (beyond the ELL index/mask overhead at tiny M)
        _require(d["compressed"] <= d["dense"] * 1.01 + 4096,
                 f"{where}.M={m}",
                 f"compressed adjacency {d['compressed']} regressed above "
                 f"dense {d['dense']}")
    top = by_m[max(by_m)]
    _require(top["compressed"] < top["dense"], f"{where}.M={max(by_m)}",
             "compressed adjacency not below dense at the largest M")


def check_speedup(payload: dict) -> None:
    where = "BENCH_speedup"
    _fields(payload, {"quick": bool, "rows": list, "m32_wire": dict,
                      "m32_partition": dict, "m32_ragged": dict,
                      "m32_packed": dict, "m32_minibatch": dict,
                      "m32_fused": dict}, where)
    modes = {r["mode"] for r in payload["rows"]}
    _require(modes == {"parallel", "compressed", "p2p", "p2p_ml"}, where,
             f"rows must cover parallel/compressed/p2p/p2p_ml, "
             f"got {sorted(modes)}")
    for i, r in enumerate(payload["rows"]):
        w = f"{where}.rows[{i}]"
        _fields(r, {"mode": str, "dataset": str,
                    "serial_per_epoch_s": numbers.Real,
                    "parallel_per_epoch_s": numbers.Real,
                    "parallel_collective_bytes": numbers.Real,
                    "adjacency_bytes": int}, w)
        _require(r["parallel_per_epoch_s"] > 0, w, "non-positive epoch time")
    by_key: dict[tuple, dict[str, dict]] = {}
    for r in payload["rows"]:
        by_key.setdefault(r["dataset"], {})[r["mode"]] = r
    for ds, d in by_key.items():
        w = f"{where}.{ds}"
        # the p2p step may never compile to MORE collective bytes than the
        # allgather oracle (equality is legitimate on block-dense M=3
        # graphs where every community neighbours every other; the strict
        # win is asserted on the sparse M=32 topology below)
        _require(d["p2p"]["parallel_collective_bytes"]
                 <= d["compressed"]["parallel_collective_bytes"], w,
                 "p2p collective bytes above the allgather transport")
        _require(d["p2p"]["scheduled_wire_bytes"]
                 <= d["p2p"]["comm_full_bytes"], w,
                 "scheduled wire bytes above the all-gather volume")
        # the multilevel partitioner may never cut more edges than the
        # BFS+KL stand-in it supersedes (p2p_ml row == p2p row but
        # partitioned by sharding.multilevel)
        _require(d["p2p_ml"]["partitioner"] == "multilevel"
                 and d["p2p"]["partitioner"] == "bfs_kl", w,
                 "p2p/p2p_ml rows carry the wrong partitioner tag")
        _require(d["p2p_ml"]["edge_cut"] <= d["p2p"]["edge_cut"], w,
                 f"multilevel cut {d['p2p_ml']['edge_cut']} above bfs_kl "
                 f"{d['p2p']['edge_cut']}")
        for mode in ("p2p", "p2p_ml"):
            _require(d[mode]["part_balance"] <= 1.0 + 1e-9, w,
                     f"{mode} partition exceeds the strict balance cap")

    m32 = payload["m32_wire"]
    w = f"{where}.m32_wire"
    _fields(m32, {"M": int, "full_bytes": int, "needed_bytes": int,
                  "wire_bytes": int, "p2p_rounds": int,
                  "wire_reduction": numbers.Real}, w)
    _require(m32["M"] == 32, w, "wire comparison must be at M=32")
    _require(m32["wire_bytes"] < m32["full_bytes"], w,
             "p2p wire bytes not reduced vs allgather at M=32")
    _require(m32["wire_bytes"] <= m32["needed_bytes"], w,
             "p2p wire bytes above the mask-derived needed volume")

    # partitioner head-to-head at M=32 on the power-law benchmark graph:
    # the multilevel pass must strictly beat the BFS+KL stand-in on cut
    # (the acceptance criterion — cut IS the p2p wire volume) with no
    # worse ELL fan-in and no more scheduled wire bytes.
    mp = payload["m32_partition"]
    w = f"{where}.m32_partition"
    _fields(mp, {"M": int, "methods": dict}, w)
    _require(set(mp["methods"]) == {"bfs_kl", "multilevel"}, w,
             f"methods must cover bfs_kl/multilevel, "
             f"got {sorted(mp['methods'])}")
    for method, q in mp["methods"].items():
        _fields(q, {"edge_cut": int, "balance": numbers.Real,
                    "max_deg": int, "wire_bytes": int,
                    "p2p_rounds": int}, f"{w}.{method}")
        _require(q["balance"] <= 1.0 + 1e-9, f"{w}.{method}",
                 "partition exceeds the strict balance cap")
    kl, ml = mp["methods"]["bfs_kl"], mp["methods"]["multilevel"]
    _require(ml["edge_cut"] < kl["edge_cut"], w,
             f"multilevel cut {ml['edge_cut']} not strictly below bfs_kl "
             f"{kl['edge_cut']} at M=32")
    _require(ml["max_deg"] <= kl["max_deg"], w,
             f"multilevel max_deg {ml['max_deg']} worse than bfs_kl "
             f"{kl['max_deg']}")
    _require(ml["wire_bytes"] <= kl["wire_bytes"], w,
             f"multilevel wire {ml['wire_bytes']} above bfs_kl "
             f"{kl['wire_bytes']}")

    # ragged (size-aware) padding on the seed-0 size-skewed power-law graph
    # at M=32: bucketed padding must undercut the global-n_pad baseline on
    # pad bytes, pad FLOPs and scheduled wire, and the row-exact wire must
    # not exceed the uniform-graph multilevel wire above — proving the
    # global pad (not the size skew) was the communication cost.
    mr = payload["m32_ragged"]
    w = f"{where}.m32_ragged"
    _fields(mr, {"M": int, "size_skew": numbers.Real, "modes": dict}, w)
    _require(mr["M"] == 32, w, "ragged comparison must be at M=32")
    _require(set(mr["modes"]) == {"global", "bucketed"}, w,
             f"modes must cover global/bucketed, got {sorted(mr['modes'])}")
    for mode, q in mr["modes"].items():
        _fields(q, {"n_pad": int, "pad_rows": int, "pad_bytes": int,
                    "pad_flops": numbers.Real, "wire_bytes": int,
                    "true_wire_bytes": int, "p2p_rounds": int},
                f"{w}.{mode}")
    gl, bu = mr["modes"]["global"], mr["modes"]["bucketed"]
    _require(bu["pad_bytes"] < gl["pad_bytes"], w,
             f"bucketed pad_bytes {bu['pad_bytes']} not below global "
             f"{gl['pad_bytes']}")
    _require(bu["pad_flops"] < gl["pad_flops"], w,
             f"bucketed pad_flops {bu['pad_flops']} not below global "
             f"{gl['pad_flops']}")
    _require(bu["wire_bytes"] < gl["wire_bytes"], w,
             f"row-exact wire {bu['wire_bytes']} not below the whole-block "
             f"wire {gl['wire_bytes']}")
    _require(bu["wire_bytes"] <= ml["wire_bytes"], w,
             f"ragged wire {bu['wire_bytes']} on the skewed graph exceeds "
             f"the m32_partition multilevel wire {ml['wire_bytes']}")

    # packed resident state on the same skewed M=32 graph: the Σ-bucket-rows
    # plane must hold strictly fewer resident Z bytes than the strided
    # (M, n_pad, C) layout, and the staged exchange schedule must hide a
    # non-zero fraction of the wire behind per-arrival-group aggregation
    # (exposed wire strictly inside the total).
    pk = payload["m32_packed"]
    w = f"{where}.m32_packed"
    _fields(pk, {"M": int, "n_shards": int, "strided_rows": int,
                 "packed_rows": int, "bucket_rows": int,
                 "strided_z_bytes": int, "packed_z_bytes": int,
                 "resident_reduction": numbers.Real, "wire_bytes": int,
                 "overlap": dict, "roofline": dict}, w)
    _require(pk["M"] == 32, w, "packed comparison must be at M=32")
    _require(pk["packed_z_bytes"] < pk["strided_z_bytes"], w,
             f"packed resident Z {pk['packed_z_bytes']} not below strided "
             f"{pk['strided_z_bytes']}")
    _require(pk["bucket_rows"] <= pk["packed_rows"] <= pk["strided_rows"],
             w, "packed rows must sit between the Σ-bucket floor and the "
                "strided row count")
    ovl = pk["overlap"]
    _fields(ovl, {"num_rounds": int, "num_groups": int,
                  "overlap_efficiency": numbers.Real,
                  "total_wire_s": numbers.Real,
                  "exposed_wire_s": numbers.Real,
                  "exposed_wire_bytes": int}, f"{w}.overlap")
    _require(ovl["overlap_efficiency"] > 0, f"{w}.overlap",
             "staged exchange hides no wire (overlap_efficiency == 0)")
    _require(ovl["exposed_wire_s"] < ovl["total_wire_s"], f"{w}.overlap",
             "exposed wire not strictly inside the total scheduled wire")
    _require(ovl["exposed_wire_bytes"] <= pk["wire_bytes"], f"{w}.overlap",
             "exposed wire bytes above the scheduled wire volume")
    rf = pk["roofline"]
    _fields(rf, {"compute_s": numbers.Real, "memory_s": numbers.Real,
                 "collective_s": numbers.Real,
                 "collective_total_s": numbers.Real,
                 "collective_exposed_bytes": numbers.Real, "dominant": str},
            f"{w}.roofline")
    _require(rf["collective_s"] <= rf["collective_total_s"], f"{w}.roofline",
             "overlap-aware collective term above the total-wire pricing")

    # stochastic community minibatching on the same skewed M=32 graph:
    # the sampled rounds' restricted exchange and resident sweep must both
    # drop ≥2× vs full batch, the mean wire ratio must track the batch
    # fraction (round padding is the only legitimate excess), and the
    # staleness-decayed penalty must keep the sampled Lagrangian within
    # the pinned gap of the full-batch run after the same round count.
    mb = payload["m32_minibatch"]
    w = f"{where}.m32_minibatch"
    _fields(mb, {"M": int, "n_shards": int,
                 "batch_fraction": numbers.Real, "num_batches": int,
                 "schedule": list, "full_wire_bytes": int,
                 "sampled_wire_bytes": list,
                 "mean_sampled_wire_bytes": numbers.Real,
                 "wire_ratio": numbers.Real, "full_state_rows": int,
                 "sampled_state_rows": list,
                 "mean_sampled_state_rows": numbers.Real,
                 "state_ratio": numbers.Real,
                 "lagrangian_full": numbers.Real,
                 "lagrangian_minibatch": numbers.Real,
                 "lagrangian_0": numbers.Real,
                 "lagrangian_gap": numbers.Real}, w)
    _require(mb["M"] == 32, w, "minibatch comparison must be at M=32")
    _require(mb["mean_sampled_wire_bytes"] * 2 <= mb["full_wire_bytes"], w,
             f"mean sampled wire {mb['mean_sampled_wire_bytes']} not ≥2× "
             f"below the full-batch wire {mb['full_wire_bytes']}")
    _require(mb["wire_ratio"] <= mb["batch_fraction"] + 0.10, w,
             f"wire ratio {mb['wire_ratio']} above batch fraction "
             f"{mb['batch_fraction']} + slack")
    _require(mb["mean_sampled_state_rows"] * 2 <= mb["full_state_rows"], w,
             f"mean sampled sweep rows {mb['mean_sampled_state_rows']} not "
             f"≥2× below full batch {mb['full_state_rows']}")
    _require(mb["lagrangian_minibatch"] < mb["lagrangian_0"], w,
             "sampled run's Lagrangian did not descend from its start")
    _require(mb["lagrangian_gap"] <= 0.25, w,
             f"sampled Lagrangian gap {mb['lagrangian_gap']} above the "
             f"pinned 25% of the full-batch value")
    # every shard appears exactly once per sampler cycle — bounded
    # staleness is what the decay rule's convergence story rests on
    seen = sorted(s for b in mb["schedule"] for s in b)
    _require(seen == list(range(mb["n_shards"])), w,
             f"sampler cycle {mb['schedule']} does not cover every shard "
             f"exactly once")

    # fused aggregation→Z-update kernel: the fused step's aggregated
    # (k, n_pad, C) HBM intermediate must vanish (strictly below the
    # unfused write+read traffic), the traced-jaxpr aggregation→dot
    # handoff count must sit at the W-update floor of one per layer and
    # strictly below the unfused step's, and the fused-vs-unfused state
    # divergence (dot-order reassociation only) stays within the pinned
    # tolerance.
    fu = payload["m32_fused"]
    w = f"{where}.m32_fused"
    _fields(fu, {"M": int, "n_shards": int, "num_layers": int,
                 "agg_rows": int, "sites": int,
                 "unfused_intermediate_bytes": int,
                 "fused_intermediate_bytes": int,
                 "gemm_out_bytes": int,
                 "traffic_reduction": numbers.Real,
                 "parity_max_delta": numbers.Real,
                 "parity_tol": numbers.Real,
                 "fused_handoffs": int, "unfused_handoffs": int}, w)
    _require(fu["M"] == 32, w, "fused comparison must be at M=32")
    _require(fu["fused_intermediate_bytes"]
             < fu["unfused_intermediate_bytes"], w,
             f"fused intermediate HBM {fu['fused_intermediate_bytes']} not "
             f"below unfused {fu['unfused_intermediate_bytes']}")
    _require(fu["fused_intermediate_bytes"] == 0, w,
             "fused aggregate must never land in HBM (VMEM scratch only)")
    _require(fu["fused_handoffs"] <= fu["num_layers"], w,
             f"fused step hands {fu['fused_handoffs']} aggregates to dots — "
             f"above the W-update floor of {fu['num_layers']}")
    _require(fu["fused_handoffs"] < fu["unfused_handoffs"], w,
             f"fused handoffs {fu['fused_handoffs']} not below unfused "
             f"{fu['unfused_handoffs']}")
    _require(fu["parity_tol"] <= 1e-6, w,
             f"parity tolerance {fu['parity_tol']} looser than the pinned "
             f"1e-6")
    _require(fu["parity_max_delta"] <= fu["parity_tol"], w,
             f"fused-vs-unfused divergence {fu['parity_max_delta']} above "
             f"the pinned tolerance {fu['parity_tol']}")


def check_serving(payload: dict) -> None:
    where = "BENCH_serving"
    _fields(payload, {"quick": bool, "M": int, "num_nodes": int,
                      "zipf_s": numbers.Real, "batch": int,
                      "embed_capacity": int, "halo_capacity": int,
                      "hit": dict, "cold": dict,
                      "speedup_p50": numbers.Real, "parity": dict,
                      "hit_path": dict, "stats": dict}, where)
    _require(payload["M"] == 32, where, "serving bench must be at M=32")

    hit, cold = payload["hit"], payload["cold"]
    _fields(hit, {"p50_ms": numbers.Real, "p99_ms": numbers.Real,
                  "qps": numbers.Real, "hit_rate": numbers.Real,
                  "wire_bytes": int}, f"{where}.hit")
    _fields(cold, {"p50_ms": numbers.Real, "p99_ms": numbers.Real,
                   "qps": numbers.Real}, f"{where}.cold")
    # steady-state Zipf(1.1) traffic must land in cache — the floor the
    # whole engine exists to clear
    _require(hit["hit_rate"] >= 0.8, f"{where}.hit",
             f"steady-state hit rate {hit['hit_rate']} below the 0.8 floor")
    # tail of the cached path stays under the cold path's *median*
    _require(hit["p99_ms"] < cold["p50_ms"], where,
             f"cached p99 {hit['p99_ms']}ms not below the cold-path p50 "
             f"{cold['p50_ms']}ms")
    _require(payload["speedup_p50"] >= 5.0, where,
             f"cached p50 speedup {payload['speedup_p50']}x below the "
             f"pinned 5x")
    # the hit path moves nothing over a wire: the compiled gather program
    # has zero collectives and zero analyze errors
    _require(hit["wire_bytes"] == 0, f"{where}.hit",
             f"hit path moves {hit['wire_bytes']} wire bytes")
    hp = payload["hit_path"]
    _fields(hp, {"analysis_errors": int, "collectives": int,
                 "full_graph_rows_bound": int}, f"{where}.hit_path")
    _require(hp["collectives"] == 0, f"{where}.hit_path",
             f"{hp['collectives']} collective(s) in the compiled hit path")
    _require(hp["analysis_errors"] == 0, f"{where}.hit_path",
             f"{hp['analysis_errors']} analyze error(s) on the hit path")
    # cache-disabled baseline runs the same compiled programs: parity is
    # bitwise, not approximate
    par = payload["parity"]
    _fields(par, {"bitwise_equal": bool, "max_delta": numbers.Real,
                  "nodes": int}, f"{where}.parity")
    _require(par["bitwise_equal"] and par["max_delta"] == 0,
             f"{where}.parity",
             f"cached vs cache-disabled embeddings differ "
             f"(max_delta={par['max_delta']})")


CHECKS = {
    "BENCH_block_sparsity.json": check_block_sparsity,
    "BENCH_speedup.json": check_speedup,
    "BENCH_serving.json": check_serving,
}


def main(root: "str | None" = None) -> int:
    base = pathlib.Path(root) if root else \
        pathlib.Path(__file__).resolve().parents[1]
    for name, check in CHECKS.items():
        path = base / name
        if not path.exists():
            raise CheckError(f"{path} missing — run the emitting benchmark "
                             f"(benchmarks/run.py --quick)")
        check(json.loads(path.read_text()))
        print(f"[check_bench] {name}: OK")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="directory holding the BENCH_*.json artifacts")
    raise SystemExit(main(root=ap.parse_args().root))
