"""Paper Figure 2: training/test accuracy of Serial ADMM, Parallel ADMM vs
Adam / Adagrad / GD / Adadelta over 50 epochs (synthetic SBM stand-ins for
Amazon Computers/Photo — Table 2 statistics, DESIGN.md)."""
from __future__ import annotations

import json

from repro.core import gcn, graph
from repro.core.serial import BaselineTrainer, SerialADMMTrainer
from repro.core.subproblems import ADMMConfig

# paper §4.2 learning rates
BASELINES = [("adam", 1e-3), ("adagrad", 1e-3), ("adadelta", 1e-3),
             ("gd", 1e-1)]


def run(dataset: str = "amazon_photo_mini", epochs: int = 50,
        hidden: int = 256, include_parallel: bool = True) -> dict:
    g = graph.synthetic_sbm(dataset, seed=0)
    hyper = 1e-3 if "computers" in dataset else 1e-4
    cfg = gcn.GCNConfig(layer_dims=(g.features.shape[1], hidden,
                                    g.num_classes))
    admm = ADMMConfig(nu=hyper, rho=hyper)

    curves = {}
    tr = SerialADMMTrainer(cfg, admm, g, seed=0)
    log = tr.train(epochs)
    curves["serial_admm"] = {"train": log.train_acc, "test": log.test_acc}
    print(f"[accuracy] serial_admm   final train "
          f"{log.train_acc[-1]:.3f} test {log.test_acc[-1]:.3f}")

    if include_parallel:
        from repro.core.parallel import ParallelADMMTrainer
        ptr = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0)
        plog = ptr.train(epochs)
        curves["parallel_admm"] = {"train": plog.train_acc,
                                   "test": plog.test_acc}
        print(f"[accuracy] parallel_admm final train "
              f"{plog.train_acc[-1]:.3f} test {plog.test_acc[-1]:.3f}")

    for opt, lr in BASELINES:
        bt = BaselineTrainer(cfg, g, opt, lr, seed=0)
        blog = bt.train(epochs)
        curves[opt] = {"train": blog.train_acc, "test": blog.test_acc}
        print(f"[accuracy] {opt:13s} final train "
              f"{blog.train_acc[-1]:.3f} test {blog.test_acc[-1]:.3f}")
    return {"dataset": dataset, "epochs": epochs, "curves": curves}


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
