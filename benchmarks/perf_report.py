"""§Perf delta report: baseline vs optimized (*__opt.json) roofline terms
for every pair that has both artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import ICI_BW, PEAK_FLOPS

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run() -> list[dict]:
    rows = []
    for opt_path in sorted(RESULTS_DIR.glob("*__opt.json")):
        base_path = Path(str(opt_path).replace("__opt", ""))
        if not base_path.exists():
            continue
        b = json.loads(base_path.read_text())["census"]
        o = json.loads(opt_path.read_text())["census"]
        name = base_path.stem
        row = {
            "pair": name,
            "compute_s": (round(b["flops"] / PEAK_FLOPS, 3),
                          round(o["flops"] / PEAK_FLOPS, 3)),
            "collective_s": (round(b["collective_bytes"] / ICI_BW, 3),
                             round(o["collective_bytes"] / ICI_BW, 3)),
            "speedup_collective": round(
                b["collective_bytes"] / max(o["collective_bytes"], 1), 1),
        }
        rows.append(row)
        print(f"[perf] {name}: compute {row['compute_s'][0]} -> "
              f"{row['compute_s'][1]} s; collective "
              f"{row['collective_s'][0]} -> {row['collective_s'][1]} s "
              f"({row['speedup_collective']}x)")
    if not rows:
        print("[perf] no __opt artifacts; run dryrun --opt first")
    return rows


if __name__ == "__main__":
    run()
