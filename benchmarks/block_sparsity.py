"""§Block-sparsity: aggregation cost scales with nnz blocks, not M².

Sweeps community count M on a power-law community graph (Barabási–Albert
inter-community topology: nnz ≈ O(M·attach), dense layout is O(M²)) and
reports, per M:

  * block density nnz/M² and the block-compressed memory ratio;
  * dense einsum vs block-compressed (ELL) aggregation wall time;
  * aggregation FLOPs for the dense reduction (2·M²·n_pad²·C) vs the
    masked/compressed path (2·nnz·n_pad²·C);
  * per-iteration collective bytes of the parallel ADMM trainer's gathers:
    full all-gather vs the neighbour-only volume (messages.gather_bytes) —
    the roofline's collective term, see benchmarks/roofline.py;
  * an end-to-end trainer sweep: ParallelADMMTrainer in dense vs compressed
    mode per M — device-resident adjacency bytes (the dense block tensor vs
    the sharded ELL rows) and per-step wall time.  Compressed bytes must
    scale with nnz blocks (~linear in M on the power-law generator), dense
    with M².

Run: PYTHONPATH=src python benchmarks/block_sparsity.py [--quick]
                                                        [--out FILE.json]
Emits machine-readable BENCH_block_sparsity.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph, messages
from repro.kernels import ops as kops

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from roofline import collective_terms  # noqa: E402  (benchmarks/roofline.py)


def _timeit(fn, *args, reps: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def sweep(ms=(4, 8, 16, 32), nodes_per_part: int = 32, c: int = 64,
          attach: int = 2, seed: int = 0) -> list[dict]:
    rows = []
    for m in ms:
        g, part = graph.synthetic_powerlaw_communities(
            m, nodes_per_part=nodes_per_part, attach=attach, seed=seed,
            feat_dim=c)
        layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                              compressed=True)
        csr = layout.compress()
        n_pad = layout.n_pad
        nnz, dense_blocks = csr.nnz, m * m

        z = jnp.asarray(layout.pack(
            np.random.default_rng(seed).normal(
                size=(g.num_nodes, c)).astype(np.float32)))
        a = jnp.asarray(layout.a_blocks)
        nbr = jnp.asarray(layout.neighbor_mask)
        ell = (jnp.asarray(csr.ell_blocks), jnp.asarray(csr.ell_indices),
               jnp.asarray(csr.ell_mask))

        dense_fn = jax.jit(lambda a, z: jnp.einsum("mrip,rpc->mic", a, z))
        masked_fn = jax.jit(lambda a, z, nb: kops.community_spmm(a, z, nb))
        ell_fn = jax.jit(kops.community_spmm_ell)

        t_dense = _timeit(dense_fn, a, z)
        t_masked = _timeit(masked_fn, a, z, nbr)
        t_ell = _timeit(ell_fn, *ell, z)

        out_d = dense_fn(a, z)
        np.testing.assert_allclose(np.asarray(ell_fn(*ell, z)),
                                   np.asarray(out_d), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(masked_fn(a, z, nbr)),
                                   np.asarray(out_d), rtol=2e-4, atol=2e-4)

        flops_dense = 2.0 * dense_blocks * n_pad * n_pad * c
        flops_sparse = 2.0 * nnz * n_pad * n_pad * c
        comm = messages.gather_bytes(layout.neighbor_mask, n_pad, [c])
        adj = messages.adjacency_bytes(layout.neighbor_mask, n_pad)
        # scheduled p2p wire volume at one agent per community (the paper's
        # deployment): ppermute rounds move true rows + round padding
        plan = messages.build_neighbor_exchange(layout.neighbor_mask, m,
                                                n_pad)
        wire = messages.exchange_bytes(plan, [c])
        coll = collective_terms(comm["full_bytes"], comm["needed_bytes"],
                                wire["wire_bytes"])
        rows.append({
            "M": m, "n_pad": n_pad, "nnz": nnz,
            "density": nnz / dense_blocks,
            "mem_ratio": csr.blocks.nbytes / layout.a_blocks.nbytes,
            "t_dense_ms": t_dense * 1e3, "t_masked_ms": t_masked * 1e3,
            "t_ell_ms": t_ell * 1e3,
            "gflops_dense": flops_dense / 1e9,
            "gflops_sparse": flops_sparse / 1e9,
            "coll_full_kb": comm["full_bytes"] / 1e3,
            "coll_needed_kb": comm["needed_bytes"] / 1e3,
            "coll_wire_kb": wire["wire_bytes"] / 1e3,
            "coll_padding_kb": wire["padding_bytes"] / 1e3,
            "p2p_rounds": wire["num_rounds"],
            "coll_s_full": coll["collective_s"],
            "coll_s_needed": coll["collective_sparse_s"],
            "coll_s_wire": coll["collective_wire_s"],
            "coll_savings": coll["collective_savings"],
            "coll_wire_savings": coll["collective_wire_savings"],
            "adj_dense_bytes": adj["dense_bytes"],
            "adj_ell_bytes": adj["ell_bytes"],
            "max_deg": adj["max_deg"],
        })
    return rows


def trainer_sweep(ms=(4, 8, 16, 32), nodes_per_part: int = 32,
                  hidden: int = 32, steps: int = 3, seed: int = 0
                  ) -> list[dict]:
    """End-to-end ParallelADMMTrainer per M: dense vs compressed
    device-resident adjacency bytes and per-step wall time."""
    from repro.core import gcn
    from repro.core.parallel import ParallelADMMTrainer
    from repro.core.subproblems import ADMMConfig

    recs = []
    for m in ms:
        g, part = graph.synthetic_powerlaw_communities(
            m, nodes_per_part=nodes_per_part, attach=2, seed=seed,
            feat_dim=16)
        cfg = gcn.GCNConfig(layer_dims=(16, hidden, g.num_classes))
        admm = ADMMConfig(nu=1e-3, rho=1e-3)
        for mode, compressed in (("dense", False), ("compressed", True)):
            tr = ParallelADMMTrainer(cfg, admm, g, num_parts=m, seed=seed,
                                     part=part, compressed=compressed)
            assert (tr.data.a_blocks is None) == compressed
            tr.step()                                    # compile
            jax.block_until_ready(tr.state.zs[-1])
            t0 = time.perf_counter()
            for _ in range(steps):
                tr.step()
            jax.block_until_ready(tr.state.zs[-1])
            per_step = (time.perf_counter() - t0) / steps
            recs.append({
                "mode": mode, "M": m, "n_pad": tr.layout.n_pad,
                "nnz_blocks": tr.layout.nnz_blocks,
                "adjacency_bytes": int(tr.data.adjacency_nbytes),
                "per_epoch_s": per_step,
            })
            print(f"[trainer] M={m:3d} {mode:10s} "
                  f"adj {recs[-1]['adjacency_bytes']/1e6:8.3f} MB  "
                  f"step {per_step*1e3:8.1f} ms")
    return recs


def main(quick: bool = False, out: "str | None" = None):
    ms = (4, 8) if quick else (4, 8, 16, 32)
    rows = sweep(ms=ms)
    hdr = (f"{'M':>3s} {'nnz':>4s} {'dens':>5s} {'mem':>5s} "
           f"{'dense_ms':>9s} {'masked_ms':>10s} {'ell_ms':>7s} "
           f"{'GF_dense':>9s} {'GF_nnz':>7s} {'coll_full':>10s} "
           f"{'coll_need':>10s} {'coll_wire':>10s}")
    print(hdr)
    for r in rows:
        print(f"{r['M']:3d} {r['nnz']:4d} {r['density']:5.2f} "
              f"{r['mem_ratio']:5.2f} {r['t_dense_ms']:9.3f} "
              f"{r['t_masked_ms']:10.3f} {r['t_ell_ms']:7.3f} "
              f"{r['gflops_dense']:9.3f} {r['gflops_sparse']:7.3f} "
              f"{r['coll_full_kb']:9.1f}k {r['coll_needed_kb']:9.1f}k "
              f"{r['coll_wire_kb']:9.1f}k")
    big = rows[-1]
    print(f"\nAt M={big['M']}: sparse path does {big['density']:.0%} of the "
          f"dense blocks — FLOPs {big['gflops_sparse']:.3f} vs "
          f"{big['gflops_dense']:.3f} GF, ELL time {big['t_ell_ms']:.3f} vs "
          f"dense {big['t_dense_ms']:.3f} ms, collective "
          f"{big['coll_wire_kb']:.0f}k scheduled p2p wire "
          f"({big['p2p_rounds']} ppermute rounds) vs {big['coll_needed_kb']:.0f}k "
          f"needed vs {big['coll_full_kb']:.0f}k all-gather bytes per round.")
    # the p2p schedule must move no more than the mask-derived need
    assert all(r["coll_wire_kb"] <= r["coll_needed_kb"] for r in rows)
    # nnz grows ~linearly in M on the power-law topology: the sparse-path
    # cost per M must grow far slower than the dense M² path
    m0, m1 = rows[0], rows[-1]
    dense_growth = m1["gflops_dense"] / m0["gflops_dense"]
    sparse_growth = m1["gflops_sparse"] / m0["gflops_sparse"]
    assert sparse_growth < dense_growth, (sparse_growth, dense_growth)
    print(f"FLOP growth {m0['M']}→{m1['M']} communities: dense "
          f"{dense_growth:.1f}×, nnz-proportional {sparse_growth:.1f}×")

    trainer = trainer_sweep(ms=ms, steps=1 if quick else 3)
    # device-resident adjacency must scale with nnz blocks, not M²
    comp = [r for r in trainer if r["mode"] == "compressed"]
    dense = [r for r in trainer if r["mode"] == "dense"]
    comp_growth = comp[-1]["adjacency_bytes"] / comp[0]["adjacency_bytes"]
    dense_growth = dense[-1]["adjacency_bytes"] / dense[0]["adjacency_bytes"]
    assert comp_growth < dense_growth, (comp_growth, dense_growth)
    print(f"Adjacency byte growth M={comp[0]['M']}→{comp[-1]['M']}: dense "
          f"{dense_growth:.1f}×, compressed {comp_growth:.1f}×")

    payload = {"quick": quick, "agg_sweep": rows, "trainer_sweep": trainer}
    out_path = pathlib.Path(out) if out else \
        pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_block_sparsity.json"
    out_path.write_text(json.dumps(payload, indent=2))
    print(f"wrote {out_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small M sweep / few reps (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
