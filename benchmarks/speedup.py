"""Paper Table 3: Serial ADMM vs Parallel ADMM wall-time / speedup.

Serial = one community, one device.  Parallel = M=3 communities on 3 host
devices (the paper used 3 agents on one Xeon; host CPU devices are real
threads, so the speedup mechanism matches), in both the dense-replicated
and the block-compressed (sharded ELL) adjacency representations; the
``p2p``/``p2p_ml`` modes run the compressed trainer under the neighbour
p2p transport with the bfs_kl vs multilevel partitioner respectively
(rows carry each partition's edge_cut / balance / max_deg).  Each
configuration runs in a subprocess so the device count can differ (XLA
locks it at first init).

The paper reports training/communication time separately; a fused XLA
program has no such boundary, so alongside wall-time we report the
*collective byte volume* of the parallel step (the communication the paper
timed) parsed from the compiled HLO, plus the device-resident adjacency
bytes each representation holds.

Run: PYTHONPATH=src python benchmarks/speedup.py [--quick] [--out FILE.json]
Emits machine-readable BENCH_speedup.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import textwrap

WORKER = textwrap.dedent("""
    import json, sys, time
    import jax
    from repro.core import graph, gcn
    from repro.core.subproblems import ADMMConfig
    mode, dataset, epochs = sys.argv[1], sys.argv[2], int(sys.argv[3])
    hidden = int(sys.argv[4])
    g = graph.synthetic_sbm(dataset, seed=0)
    hyper = 1e-3 if "computers" in dataset else 1e-4
    cfg = gcn.GCNConfig(layer_dims=(g.features.shape[1], hidden,
                                    g.num_classes))
    admm = ADMMConfig(nu=hyper, rho=hyper)
    adjacency_bytes = 0
    if mode == "serial":
        from repro.core.serial import SerialADMMTrainer
        tr = SerialADMMTrainer(cfg, admm, g, seed=0)
        step = tr.step
        adjacency_bytes = int(tr.a_tilde.nbytes)
    else:
        from repro.core.parallel import ParallelADMMTrainer, TrainerConfig
        partitioner = "multilevel" if mode == "p2p_ml" else "bfs_kl"
        MODES = {
            "parallel": TrainerConfig.dense(partitioner=partitioner),
            "compressed": TrainerConfig(compressed=True,
                                        transport="allgather",
                                        partitioner=partitioner),
            "p2p": TrainerConfig.p2p(partitioner=partitioner),
            "p2p_ml": TrainerConfig.p2p(partitioner=partitioner),
        }
        tr = ParallelADMMTrainer(cfg, admm, g, num_parts=3, seed=0,
                                 config=MODES[mode])
        step = tr.step
        adjacency_bytes = int(tr.data.adjacency_nbytes)
    step(); jax.block_until_ready(tr.state.zs[-1])   # compile
    t0 = time.perf_counter()
    for _ in range(epochs):
        step()
    jax.block_until_ready(tr.state.zs[-1])
    total = time.perf_counter() - t0
    from repro.launch import roofline
    if mode == "serial":
        lowered = tr._step.lower(tr.a_tilde, tr.z0, tr.labels,
                                 tr.train_mask, tr.state)
    else:
        lowered = tr._step.lower(tr.state)
    census = roofline.hlo_census(lowered.compile().as_text())
    acc = tr._metrics(tr.state)
    comm = {}
    if mode != "serial":
        part_q = tr.partition_stats
        comm = {"scheduled_wire_bytes": int(tr.comm_stats["wire_bytes"]),
                "needed_bytes": int(tr.comm_stats["needed_bytes"]),
                "full_bytes": int(tr.comm_stats["full_bytes"]),
                "partitioner": tr.partitioner,
                "edge_cut": int(part_q["edge_cut"]),
                "part_balance": float(part_q["balance"]),
                "part_max_deg": int(part_q["max_deg"])}
    print(json.dumps({"mode": mode, "total_s": total,
                      "per_epoch_s": total / epochs,
                      "per_device_flops": float(census.flops),
                      "collective_bytes": float(census.collective_bytes),
                      "adjacency_bytes": adjacency_bytes,
                      "test_acc": float(acc[1]), **comm}))
""")


def _run(mode: str, dataset: str, epochs: int, hidden: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
        ("1" if mode == "serial" else "3")
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-c", WORKER, mode, dataset, str(epochs),
         str(hidden)],
        capture_output=True, text=True, env=env, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(epochs: int = 20, hidden: int = 256,
        datasets=("amazon_computers_mini", "amazon_photo_mini")) -> list:
    rows = []
    for ds in datasets:
        serial = _run("serial", ds, epochs, hidden)
        for mode in ("parallel", "compressed", "p2p", "p2p_ml"):
            parallel = _run(mode, ds, epochs, hidden)
            speedup = serial["total_s"] / parallel["total_s"]
            # analytic speedup: per-agent compute ratio from the HLO census —
            # what the wall clock would show on hardware with ≥M real cores
            # (this container has ONE core, so threads serialize; the paper's
            # Xeon had many)
            flops_ratio = (serial["per_device_flops"]
                           / max(parallel["per_device_flops"], 1.0))
            rows.append({
                "mode": mode,
                "dataset": ds,
                "serial_total_s": round(serial["total_s"], 3),
                "parallel_total_s": round(parallel["total_s"], 3),
                "serial_per_epoch_s": round(serial["per_epoch_s"], 4),
                "parallel_per_epoch_s": round(parallel["per_epoch_s"], 4),
                "speedup": round(speedup, 2),
                "analytic_compute_speedup": round(flops_ratio, 2),
                "parallel_collective_bytes": parallel["collective_bytes"],
                "scheduled_wire_bytes": parallel.get("scheduled_wire_bytes"),
                "comm_full_bytes": parallel.get("full_bytes"),
                "partitioner": parallel.get("partitioner"),
                "edge_cut": parallel.get("edge_cut"),
                "part_balance": parallel.get("part_balance"),
                "part_max_deg": parallel.get("part_max_deg"),
                "adjacency_bytes": parallel["adjacency_bytes"],
                "serial_adjacency_bytes": serial["adjacency_bytes"],
                "serial_test_acc": round(serial["test_acc"], 3),
                "parallel_test_acc": round(parallel["test_acc"], 3),
            })
            print(f"[speedup] {ds} ({mode}): serial {serial['total_s']:.2f}s "
                  f"parallel {parallel['total_s']:.2f}s -> {speedup:.2f}x "
                  f"wall-clock (1 CPU core), {flops_ratio:.2f}x per-agent "
                  f"compute, adjacency {parallel['adjacency_bytes']/1e6:.2f} "
                  f"MB (paper: 3.30x/2.98x on 3 agents)")
    return rows


def wire_comparison(m: int = 32, hidden: int = 64) -> dict:
    """Analytic transport comparison at M communities, one agent each (the
    paper's deployment, past what this container can host as devices):
    all-gather full volume vs mask-derived need vs the scheduled p2p wire
    (ppermute rounds: true rows + round padding, messages.exchange_bytes).
    """
    from repro.core import graph, messages
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=32, attach=2, seed=0, feat_dim=hidden)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True)
    stats = messages.gather_bytes(layout.neighbor_mask, layout.n_pad,
                                  [hidden])
    plan = messages.build_neighbor_exchange(layout.neighbor_mask, m,
                                            layout.n_pad)
    stats.update(messages.exchange_bytes(plan, [hidden]))
    messages.verify_transport_bytes(stats)
    out = {"M": m,
           "full_bytes": stats["full_bytes"],
           "needed_bytes": stats["needed_bytes"],
           "wire_bytes": stats["wire_bytes"],
           "padding_bytes": stats["padding_bytes"],
           "p2p_rounds": stats["num_rounds"],
           "wire_reduction": round(
               1.0 - stats["wire_bytes"] / stats["full_bytes"], 4)}
    print(f"[speedup] M={m} transport volume/iteration-payload: all-gather "
          f"{out['full_bytes']/1e3:.0f}kB -> p2p wire "
          f"{out['wire_bytes']/1e3:.0f}kB over {out['p2p_rounds']} ppermute "
          f"rounds ({out['wire_reduction']:.0%} reduction)")
    return out


def partition_comparison(m: int = 32, hidden: int = 64) -> dict:
    """Partitioner quality head-to-head on the M=32 power-law benchmark
    graph: bfs_kl (the original stand-in) vs the multilevel
    coarsen→partition→uncoarsen pass (sharding.multilevel).  Per method:
    edge cut (== the cross-community block volume the p2p transport wires),
    balance vs the strict cap, block max_deg (the ELL fan-in every shard
    pays), and the scheduled NeighborExchange wire bytes the partition
    induces at one agent per community.
    """
    from repro.core import graph, messages
    g, _ = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=32, attach=2, seed=0, feat_dim=hidden)
    out = {"M": m, "num_edges": int(g.num_edges), "methods": {}}
    for method in ("bfs_kl", "multilevel"):
        part = graph.partition_graph(g.num_nodes, g.edges, m, seed=0,
                                     method=method)
        q = graph.partition_quality(g.num_nodes, g.edges, part, m)
        layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                              compressed=True)
        plan = messages.build_neighbor_exchange(layout.neighbor_mask, m,
                                                layout.n_pad)
        wire = messages.exchange_bytes(plan, [hidden])
        out["methods"][method] = {
            "edge_cut": q["edge_cut"],
            "cut_frac": round(q["cut_frac"], 4),
            "balance": round(q["balance"], 4),
            "max_deg": q["max_deg"],
            "nnz_blocks": q["nnz_blocks"],
            "n_pad": layout.n_pad,
            "wire_bytes": wire["wire_bytes"],
            "p2p_rounds": wire["num_rounds"],
        }
    kl, ml = out["methods"]["bfs_kl"], out["methods"]["multilevel"]
    print(f"[speedup] M={m} partitioner: bfs_kl cut {kl['edge_cut']} "
          f"(max_deg {kl['max_deg']}, wire {kl['wire_bytes']/1e3:.0f}kB) -> "
          f"multilevel cut {ml['edge_cut']} (max_deg {ml['max_deg']}, wire "
          f"{ml['wire_bytes']/1e3:.0f}kB, "
          f"{1 - ml['edge_cut']/kl['edge_cut']:.0%} fewer cut edges)")
    return out


def ragged_comparison(m: int = 32, hidden: int = 64,
                      size_skew: float = 1.0) -> dict:
    """Size-aware padding head-to-head on the seed-0 size-skewed power-law
    graph at M=32 (Zipf community sizes, large communities on the BA
    periphery — graph.synthetic_powerlaw_communities(size_skew=...)), one
    agent per community.  Per pad mode: the residual-padding accounting
    (messages.pad_stats — pad rows/bytes the payloads carry, pad FLOPs the
    block aggregation spends) and the scheduled NeighborExchange wire —
    whole-n_pad-block messages under ``global``, row-exact payloads over
    size-bucketed sub-rounds under ``bucketed``.  check_bench.py guards
    that bucketed padding undercuts global on every axis and that the
    ragged wire stays at or below the uniform-graph multilevel wire
    (``m32_partition``) — pad waste, not size skew, was the cost.
    """
    import numpy as np
    from repro.core import graph, messages
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=32, attach=2, seed=0, feat_dim=hidden,
        size_skew=size_skew)
    sizes = np.bincount(part, minlength=m)
    out = {"M": m, "size_skew": size_skew,
           "max_size": int(sizes.max()), "min_size": int(sizes.min()),
           "modes": {}}
    for pad_mode in ("global", "bucketed"):
        layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                              compressed=True,
                                              pad_mode=pad_mode)
        plan = messages.build_neighbor_exchange(
            layout.neighbor_mask, m, layout.n_pad,
            sizes=layout.sizes if pad_mode == "bucketed" else None)
        wire = messages.exchange_bytes(plan, [hidden])
        pad = messages.pad_stats(layout.neighbor_mask, layout.sizes,
                                 layout.row_counts, layout.n_pad, [hidden])
        out["modes"][pad_mode] = {
            "n_pad": layout.n_pad,
            "pad_rows": pad["pad_rows"],
            "pad_bytes": pad["pad_bytes"],
            "pad_flops": pad["pad_flops"],
            "pad_flop_frac": round(pad["pad_flop_frac"], 4),
            "wire_bytes": wire["wire_bytes"],
            "true_wire_bytes": wire["p2p_needed_bytes"],
            "p2p_rounds": wire["num_rounds"],
        }
    gl, bu = out["modes"]["global"], out["modes"]["bucketed"]
    print(f"[speedup] M={m} skew={size_skew} ragged padding: global pad "
          f"{gl['pad_bytes']/1e3:.0f}kB/iter-payload "
          f"({100*gl['pad_flop_frac']:.0f}% pad FLOPs), wire "
          f"{gl['wire_bytes']/1e3:.0f}kB -> bucketed pad "
          f"{bu['pad_bytes']/1e3:.0f}kB ({100*bu['pad_flop_frac']:.0f}%), "
          f"row-exact wire {bu['wire_bytes']/1e3:.0f}kB over "
          f"{bu['p2p_rounds']} rounds")
    return out


def packed_comparison(m: int = 32, hidden: int = 64,
                      size_skew: float = 1.0, n_shards: int = 4) -> dict:
    """Packed Σ-bucket-rows resident state vs the strided (M, n_pad, C)
    layout on the seed-0 size-skewed power-law graph at M=32, over a
    ``n_shards`` mesh (k = M/n_shards communities per shard).

    The strided layout prices every resident Z/U/z0 tensor at M·n_pad
    rows — the single largest community pads everyone.  The packed device
    layout (graph.CommunityLayout.device_layout) stores each shard's
    lanes back to back at their bucket row counts, so resident rows drop
    to the shard-max Σ-bucket-rows; check_bench.py guards that the packed
    Z bytes sit strictly below strided here.  The overlap section prices
    the round schedule's *exposed* wire (messages.overlap_stats): what
    the double-buffered per-arrival-group aggregation cannot hide behind
    compute, fed to roofline_terms' overlap-aware collective term.
    """
    import numpy as np
    from repro.core import graph, messages
    from repro.launch.roofline import roofline_terms
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=32, attach=2, seed=0, feat_dim=hidden,
        size_skew=size_skew)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    dl = layout.device_layout(n_shards)
    plan = messages.build_neighbor_exchange(
        layout.neighbor_mask, n_shards, layout.n_pad,
        sizes=layout.sizes, row_counts=layout.eff_row_counts())
    ov = messages.overlap_stats(plan, layout.neighbor_mask, [hidden],
                                enabled=True)
    wire = messages.exchange_bytes(plan, [hidden])
    strided_rows = m * layout.n_pad
    packed_rows = dl.total_rows
    # aggregation FLOPs available to hide the wire: 2·rows·rows·C per
    # stored ELL block pair is what overlap_stats already models; here we
    # price the roofline with the scheduled wire vs its exposed remainder
    terms = roofline_terms(
        flops=ov["hidden_wire_s"] * float(ov["model"]["peak_flops"]),
        hbm_bytes=packed_rows * hidden * 4,
        collective_total=wire["wire_bytes"],
        exposed_collective=ov["exposed_wire_bytes"])
    out = {
        "M": m, "n_shards": n_shards, "size_skew": size_skew,
        "n_pad": layout.n_pad,
        "strided_rows": int(strided_rows),
        "packed_rows": int(packed_rows),
        "bucket_rows": int(dl.true_rows),
        "node_rows": int(np.asarray(layout.sizes).sum()),
        "strided_z_bytes": int(strided_rows * hidden * 4),
        "packed_z_bytes": int(packed_rows * hidden * 4),
        "resident_reduction": round(1.0 - packed_rows / strided_rows, 4),
        "wire_bytes": int(wire["wire_bytes"]),
        "p2p_rounds": int(wire["num_rounds"]),
        "overlap": {
            "num_rounds": int(ov["num_rounds"]),
            "num_groups": int(ov["num_groups"]),
            "overlap_efficiency": float(ov["overlap_efficiency"]),
            "total_wire_s": float(ov["total_wire_s"]),
            "exposed_wire_s": float(ov["exposed_wire_s"]),
            "exposed_wire_bytes": int(ov["exposed_wire_bytes"]),
        },
        "roofline": {k: (float(v) if not isinstance(v, str) else v)
                     for k, v in terms.items()},
    }
    print(f"[speedup] M={m} skew={size_skew} packed state over {n_shards} "
          f"shards: strided {out['strided_z_bytes']/1e3:.0f}kB resident Z "
          f"-> packed {out['packed_z_bytes']/1e3:.0f}kB "
          f"({out['resident_reduction']:.0%} down, Σ-bucket floor "
          f"{out['bucket_rows']} rows); overlap hides "
          f"{100*out['overlap']['overlap_efficiency']:.2f}% of "
          f"{out['wire_bytes']/1e3:.0f}kB wire over "
          f"{out['overlap']['num_rounds']} rounds")
    return out


MB_WORKER = textwrap.dedent("""
    import json, sys
    import numpy as np, jax
    from repro.core import graph, gcn
    from repro.core.parallel import ParallelADMMTrainer, TrainerConfig, AXIS
    from repro.core.subproblems import ADMMConfig
    from repro.util.compat import make_mesh
    m, hidden, epochs = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
    frac = float(sys.argv[4])
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=12, attach=1, seed=0, feat_dim=hidden,
        size_skew=1.0)
    cfg = gcn.GCNConfig(layer_dims=(hidden, hidden,
                                    int(np.asarray(g.labels).max()) + 1))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    mesh = make_mesh((4,), (AXIS,), devices=jax.devices()[:4])
    out = {}
    for name, cfg_t in (("full", TrainerConfig.packed()),
                        ("minibatch",
                         TrainerConfig.minibatch(batch_fraction=frac))):
        tr = ParallelADMMTrainer(cfg, admm, g, num_parts=m, seed=0,
                                 part=part, mesh=mesh, config=cfg_t)
        lag0 = float(tr._lagrangian(tr.state))
        for _ in range(epochs):
            tr.step()
        out[name] = {"lagrangian_0": lag0,
                     "lagrangian": float(tr._lagrangian(tr.state)),
                     "minibatch": {k: v for k, v in
                                   tr.comm_stats["minibatch"].items()}}
    print(json.dumps(out))
""")


def minibatch_comparison(m: int = 32, hidden: int = 64,
                         size_skew: float = 1.0, n_shards: int = 4,
                         batch_fraction: float = 0.25,
                         epochs: int = 10) -> dict:
    """Stochastic community minibatching on the seed-0 size-skewed M=32
    power-law graph over a 4-shard mesh.

    Analytic half: the batch sampler's cycle-0 schedule
    (sharding.partition.CommunityBatchSampler, Σ-bucket-rows balanced)
    prices every sampled round's restricted exchange
    (messages.restrict_exchange — only messages *into* sampled shards
    survive) and the sampled resident sweep rows, against the full-batch
    plan.  check_bench.py guards both drop ≥2× and that the wire ratio
    stays ≤ batch_fraction + slack (round padding is the only excess).

    Measured half: a 4-host-device subprocess trains the full-batch
    packed trainer and the ``batch_fraction`` minibatch trainer for the
    same ``epochs`` rounds and reports both augmented Lagrangians — the
    staleness-decayed penalty (docs/minibatch.md) must keep the sampled
    run's final Lagrangian within the pinned gap of full batch.
    """
    import numpy as np
    from repro.core import graph, messages
    from repro.sharding.partition import CommunityBatchSampler
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=32, attach=2, seed=0, feat_dim=hidden,
        size_skew=size_skew)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    plan = messages.build_neighbor_exchange(
        layout.neighbor_mask, n_shards, layout.n_pad,
        sizes=layout.sizes, row_counts=layout.eff_row_counts())
    full_wire = int(messages.exchange_bytes(plan, [hidden])["wire_bytes"])
    rc = np.asarray(layout.eff_row_counts(),
                    dtype=np.int64).reshape(n_shards, -1)
    shard_rows = rc.sum(axis=1)
    sampler = CommunityBatchSampler(n_shards, batch_fraction, seed=0,
                                    weights=shard_rows.astype(np.float64))
    wires, rows = [], []
    for b in sampler.cycle(0):
        sub = plan if len(b) == n_shards else \
            messages.restrict_exchange(plan, frozenset(b))
        wires.append(int(messages.exchange_bytes(
            sub, [hidden])["wire_bytes"]))
        rows.append(int(shard_rows[list(b)].sum()))

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-c", MB_WORKER, str(m), "16", str(epochs),
         str(batch_fraction)],
        capture_output=True, text=True, env=env, check=True)
    run = json.loads(proc.stdout.strip().splitlines()[-1])

    out = {
        "M": m, "n_shards": n_shards, "size_skew": size_skew,
        "batch_fraction": batch_fraction,
        "num_batches": int(sampler.num_batches),
        "schedule": [list(b) for b in sampler.cycle(0)],
        "full_wire_bytes": full_wire,
        "sampled_wire_bytes": wires,
        "mean_sampled_wire_bytes": float(np.mean(wires)),
        "wire_ratio": round(float(np.mean(wires)) / full_wire, 4),
        "full_state_rows": int(shard_rows.sum()),
        "sampled_state_rows": rows,
        "mean_sampled_state_rows": float(np.mean(rows)),
        "state_ratio": round(float(np.mean(rows)) / float(shard_rows.sum()),
                             4),
        "epochs": epochs,
        "lagrangian_full": run["full"]["lagrangian"],
        "lagrangian_minibatch": run["minibatch"]["lagrangian"],
        "lagrangian_0": run["full"]["lagrangian_0"],
        "lagrangian_gap": round(
            (run["minibatch"]["lagrangian"] - run["full"]["lagrangian"])
            / max(abs(run["full"]["lagrangian"]), 1e-9), 4),
    }
    print(f"[speedup] M={m} skew={size_skew} minibatch f={batch_fraction}: "
          f"wire {full_wire/1e3:.0f}kB -> mean sampled "
          f"{out['mean_sampled_wire_bytes']/1e3:.0f}kB "
          f"({out['wire_ratio']:.0%}), sweep rows "
          f"{out['full_state_rows']} -> {out['mean_sampled_state_rows']:.0f} "
          f"({out['state_ratio']:.0%}); Lagrangian after {epochs} rounds "
          f"full {out['lagrangian_full']:.4f} vs sampled "
          f"{out['lagrangian_minibatch']:.4f} "
          f"(gap {out['lagrangian_gap']:+.1%})")
    return out


FU_WORKER = textwrap.dedent("""
    import json, sys
    import numpy as np, jax
    import jax.numpy as jnp
    from repro.core import graph, gcn
    from repro.core.parallel import ParallelADMMTrainer, TrainerConfig, AXIS
    from repro.core.subproblems import ADMMConfig
    from repro.util.compat import make_mesh
    from repro.analysis.rules.memory import fused_agg_handoffs
    m, hidden, epochs = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=12, attach=1, seed=0, feat_dim=hidden,
        size_skew=1.0)
    cfg = gcn.GCNConfig(layer_dims=(hidden, hidden,
                                    int(np.asarray(g.labels).max()) + 1))
    admm = ADMMConfig(nu=1e-3, rho=1e-3)
    mesh = make_mesh((4,), (AXIS,), devices=jax.devices()[:4])
    out = {"num_layers": cfg.num_layers}
    trs = {}
    for name, fused in (("unfused", False), ("fused", True)):
        tr = ParallelADMMTrainer(
            cfg, admm, g, num_parts=m, seed=0, part=part, mesh=mesh,
            config=TrainerConfig(compressed=True, transport="p2p",
                                 pad_mode="bucketed", packed=True,
                                 fused=fused))
        jx = jax.make_jaxpr(tr._step)(tr.state)
        out[name + "_handoffs"] = len(fused_agg_handoffs(jx,
                                                         tr.layout.n_pad))
        trs[name] = tr
    def delta(a, b):
        return max(
            max(float(jnp.max(jnp.abs(x - y)))
                for x, y in zip(a.weights, b.weights)),
            max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(a.zs, b.zs)),
            float(jnp.max(jnp.abs(a.u - b.u))))
    # per-iteration parity from a shared input state: the backtracking
    # line searches branch on loss comparisons, so across iterations a
    # dot-order epsilon can flip a step count and the trajectories
    # diverge discretely — parity is pinned per step, not per trajectory
    # (copies because the step jit donates its input buffers)
    state = trs["unfused"].state
    deltas = []
    for _ in range(epochs):
        fused_next = trs["fused"]._step(jax.tree.map(jnp.copy, state))
        state = trs["unfused"]._step(state)
        deltas.append(delta(state, fused_next))
    out["parity_max_delta"] = max(deltas)
    out["lagrangian_unfused"] = float(trs["unfused"]._lagrangian(state))
    out["lagrangian_fused"] = float(trs["fused"]._lagrangian(fused_next))
    print(json.dumps(out))
""")


def fused_comparison(m: int = 32, hidden: int = 64,
                     size_skew: float = 1.0, n_shards: int = 4,
                     epochs: int = 3) -> dict:
    """Fused aggregation→Z-update kernel vs the two-step packed path on
    the seed-0 power-law graph at M=32 over a 4-shard mesh.

    Analytic half: per shard per iteration, every Z-update
    aggregation→GEMM site unfused writes its aggregated (k, n_pad, C_in)
    stack to HBM and reads it back for the GEMM — the fused kernel keeps
    it in VMEM scratch, so its HBM intermediate traffic is zero
    (roofline.fused_agg_traffic prices both).  Measured half: a
    4-host-device subprocess steps the fused and unfused packed trainers
    from a shared state each round and reports the max per-iteration
    W/Z/U divergence (the fused GEMM reassociates (A·Z)·W to A·(Z·W) —
    dot-order tolerance, pinned at 1e-6 by check_bench.py; the
    line-search branches make *trajectory* divergence discrete, so
    parity is per step) plus the traced jaxpr's
    aggregation→dot handoff counts (the memory/fused-no-intermediate
    dataflow walk): the fused step must sit at the W-update floor of one
    per layer, strictly below the unfused step.
    """
    from repro.core import graph
    from repro.launch.roofline import fused_agg_traffic
    g, part = graph.synthetic_powerlaw_communities(
        m, nodes_per_part=32, attach=2, seed=0, feat_dim=hidden,
        size_skew=size_skew)
    layout = graph.build_community_layout(g.num_nodes, g.edges, part,
                                          compressed=True,
                                          pad_mode="bucketed")
    num_classes = g.num_classes
    dims = [hidden, hidden, num_classes]
    L = len(dims) - 1
    # the fused Z-update sites per iteration: target1 (hidden layers),
    # q (hidden layers), and the Z_L target b evaluated twice by the
    # penultimate refresh (b, b_new)
    sites = [(dims[l - 1], dims[l]) for l in range(1, L)] \
        + [(dims[l], dims[l + 1]) for l in range(1, L)] \
        + [(dims[L - 1], dims[L])] * 2
    traffic = fused_agg_traffic((m // n_shards) * layout.n_pad, sites)

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-c", FU_WORKER, str(m), "16", str(epochs)],
        capture_output=True, text=True, env=env, check=True)
    run = json.loads(proc.stdout.strip().splitlines()[-1])

    out = {
        "M": m, "n_shards": n_shards, "hidden": hidden,
        "n_pad": int(layout.n_pad),
        "num_layers": int(run["num_layers"]),
        **traffic,
        "traffic_reduction": round(
            1.0 - traffic["fused_intermediate_bytes"]
            / max(traffic["unfused_intermediate_bytes"], 1), 4),
        "epochs": epochs,
        "parity_max_delta": float(run["parity_max_delta"]),
        "parity_tol": 1e-6,
        "fused_handoffs": int(run["fused_handoffs"]),
        "unfused_handoffs": int(run["unfused_handoffs"]),
        "lagrangian_fused": run["lagrangian_fused"],
        "lagrangian_unfused": run["lagrangian_unfused"],
    }
    print(f"[speedup] M={m} fused agg→GEMM over {n_shards} shards: "
          f"intermediate HBM "
          f"{out['unfused_intermediate_bytes']/1e3:.0f}kB/shard/iter -> "
          f"{out['fused_intermediate_bytes']}B "
          f"({out['traffic_reduction']:.0%} down, {out['sites']} sites); "
          f"agg→dot handoffs {out['unfused_handoffs']} -> "
          f"{out['fused_handoffs']}; parity after {epochs} rounds "
          f"{out['parity_max_delta']:.2e} (tol {out['parity_tol']:.0e})")
    return out


def main(quick: bool = False, out: "str | None" = None):
    if quick:
        rows = run(epochs=2, hidden=32, datasets=("amazon_photo_mini",))
    else:
        rows = run()
    payload = {"quick": quick, "rows": rows, "m32_wire": wire_comparison(),
               "m32_partition": partition_comparison(),
               "m32_ragged": ragged_comparison(),
               "m32_packed": packed_comparison(),
               "m32_minibatch": minibatch_comparison(),
               "m32_fused": fused_comparison()}
    out_path = pathlib.Path(out) if out else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_speedup.json"
    out_path.write_text(json.dumps(payload, indent=2))
    print(f"wrote {out_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny run (CI smoke): 1 dataset, 2 epochs")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    print(json.dumps(main(quick=args.quick, out=args.out)["rows"], indent=2))
