"""§Roofline: three-term roofline table from the dry-run JSONs.

Reads results/dryrun/*.json (produced by ``python -m repro.launch.dryrun``)
and prints, per (arch × shape × mesh): compute / memory / collective terms
in seconds, the dominant term, MODEL_FLOPS / HLO_FLOPs usefulness ratio,
and a one-line "what would move the dominant term" note.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"

MOVE_NOTES = {
    "compute_s": "shard more FLOP-dense dims / cut remat recompute "
                 "(fewer checkpoint boundaries) / causal block skipping",
    "memory_s": "fuse CE with unembed, keep activations bf16, widen "
                "microbatches to raise arithmetic intensity",
    "collective_s": "overlap collectives with compute, reduce-scatter "
                    "instead of all-reduce for grads, shrink expert "
                    "all-to-all payload (bf16 router combine)",
}


def collective_terms(full_bytes: float,
                     needed_bytes: float | None = None,
                     wire_bytes: float | None = None) -> dict:
    """Collective roofline term, with the block-sparse (neighbour-only)
    volume when known.  The GCN parallel trainer records ``comm_stats``
    (core/messages.gather_bytes): an all-gather transport moves
    ``full_bytes`` per iteration, the masks bound the neighbour-only need
    at ``needed_bytes`` (ratio nnz(neighbour blocks)/M²), and the p2p
    ``ppermute`` schedule actually moves ``wire_bytes`` (true scheduled
    rows + round padding, core/messages.exchange_bytes) — the volume the
    collective term should be priced at when the p2p transport runs.
    """
    out = {"collective_s": full_bytes / ICI_BW}
    if needed_bytes is not None:
        out["collective_sparse_s"] = needed_bytes / ICI_BW
        out["collective_savings"] = 1.0 - (
            needed_bytes / full_bytes if full_bytes else 0.0)
    if wire_bytes is not None:
        out["collective_wire_s"] = wire_bytes / ICI_BW
        out["collective_wire_savings"] = 1.0 - (
            wire_bytes / full_bytes if full_bytes else 0.0)
    return out


def analyze(path: Path) -> dict:
    r = json.loads(path.read_text())
    census = r["census"]
    flops = census["flops"]
    hbm_hi = census["hbm_bytes"]
    hbm_lo = r.get("analytic_hbm_bytes", hbm_hi)
    coll = census["collective_bytes"]
    coll_t = collective_terms(coll, r.get("collective_needed_bytes"),
                              r.get("collective_wire_bytes"))
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_lo_s": hbm_lo / HBM_BW,
        "memory_hi_s": hbm_hi / HBM_BW,
        # scheduled p2p wire volume when the run recorded one, else the
        # mask-derived neighbour bound, else the raw census (GCN trainer)
        "collective_s": coll_t.get(
            "collective_wire_s", coll_t.get("collective_sparse_s",
                                            coll_t["collective_s"])),
        "collective_dense_s": coll_t["collective_s"],
    }
    # dominant term: memory judged by its analytic floor (the census bound
    # carries CPU-fusion-granularity inflation; see roofline.py docstring)
    cand = {"compute_s": terms["compute_s"],
            "memory_s": terms["memory_lo_s"],
            "collective_s": terms["collective_s"]}
    dominant = max(cand, key=cand.get)
    model_fl = r.get("model_flops", 0.0)
    ratio = model_fl / (flops * r["chips"]) if flops else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "step": r["step"], **{k: round(v, 4) for k, v in terms.items()},
        "dominant": dominant,
        "useful_ratio": round(ratio, 3),
        "peak_gib": round((r["memory"]["peak_bytes"] or 0) / 2**30, 2),
        "note": MOVE_NOTES[dominant],
    }


def run(mesh_filter: str = "16x16") -> list[dict]:
    rows = []
    for path in sorted(RESULTS_DIR.glob(f"*__{mesh_filter}.json")):
        rows.append(analyze(path))
    if not rows:
        print(f"[roofline] no dry-run results in {RESULTS_DIR} "
              f"(run python -m repro.launch.dryrun first)")
        return rows
    hdr = (f"{'arch':24s} {'shape':11s} {'compute_s':>9s} {'mem_lo_s':>9s} "
           f"{'mem_hi_s':>9s} {'coll_s':>8s} {'dominant':>12s} "
           f"{'useful':>7s} {'peakGiB':>8s}")
    print(hdr)
    for row in rows:
        print(f"{row['arch']:24s} {row['shape']:11s} "
              f"{row['compute_s']:9.3f} {row['memory_lo_s']:9.3f} "
              f"{row['memory_hi_s']:9.3f} {row['collective_s']:8.3f} "
              f"{row['dominant']:>12s} {row['useful_ratio']:7.3f} "
              f"{row['peak_gib']:8.2f}")
    return rows


if __name__ == "__main__":
    run()
