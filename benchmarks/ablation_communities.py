"""Beyond-paper ablation: number of communities M vs accuracy / edge cut /
communication volume / per-agent compute.

The paper fixes M=3.  Each M runs in a subprocess with M host devices (one
per agent), so the collective census and per-device FLOPs reflect a real
M-agent deployment: per-agent compute shrinks ~1/M while the gathered
message volume and the edge cut grow — the trade-off the paper's community
splitting navigates.

Every row additionally reports the partition-quality head-to-head
(edge_cut / balance / max_deg) of both ``partition_graph`` methods at that
M — ``bfs_kl`` (the original stand-in) vs ``multilevel``
(sharding.multilevel, the METIS-scheme pass the trainer now defaults to
here via ``--partitioner``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

WORKER = textwrap.dedent("""
    import json, sys
    import numpy as np
    from repro.core import gcn, graph
    from repro.core.subproblems import ADMMConfig
    from repro.core.parallel import ParallelADMMTrainer
    from repro.launch import roofline
    dataset, m, epochs, hidden, partitioner = (
        sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
        sys.argv[5])
    g = graph.synthetic_sbm(dataset, seed=0)
    hyper = 1e-3 if "computers" in dataset else 1e-4
    cfg = gcn.GCNConfig(layer_dims=(g.features.shape[1], hidden,
                                    g.num_classes))
    tr = ParallelADMMTrainer(cfg, ADMMConfig(nu=hyper, rho=hyper), g,
                             num_parts=m, seed=0, partitioner=partitioner)
    # partition-quality head-to-head at this M: the cut sets the message
    # volume, max_deg the ELL fan-in, balance the padding waste
    quality = {
        method: {k: q[k] for k in ("edge_cut", "cut_frac", "balance",
                                   "max_deg")}
        for method, q in (
            (meth, graph.partition_quality(
                g.num_nodes, g.edges,
                graph.partition_graph(g.num_nodes, g.edges, m, seed=0,
                                      method=meth), m))
            for meth in ("bfs_kl", "multilevel"))}
    census = roofline.hlo_census(
        tr._step.lower(tr.state).compile().as_text())
    log = tr.train(epochs)
    print(json.dumps({
        "M": m,
        "partitioner": tr.partitioner,
        "edge_cut_frac": round(tr.partition_stats["cut_frac"], 3),
        "partition_quality": quality,
        "collective_bytes_per_iter": float(census.collective_bytes),
        "per_device_flops": float(census.flops),
        "test_acc": round(float(log.test_acc[-1]), 3),
    }))
""")


def run(dataset: str = "amazon_photo_mini", epochs: int = 25,
        hidden: int = 128, parts=(1, 2, 3, 4, 6),
        partitioner: str = "multilevel") -> list[dict]:
    rows = []
    for m in parts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={m}"
        env.setdefault("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-c", WORKER, dataset, str(m), str(epochs),
             str(hidden), partitioner],
            capture_output=True, text=True, env=env, check=True)
        row = json.loads(out.stdout.strip().splitlines()[-1])
        rows.append(row)
        q = row["partition_quality"]
        print(f"[ablation] M={row['M']} [{row['partitioner']}]: cut "
              f"{row['edge_cut_frac']:.3f} "
              f"(bfs_kl {q['bfs_kl']['edge_cut']} vs multilevel "
              f"{q['multilevel']['edge_cut']}, max_deg "
              f"{q['bfs_kl']['max_deg']} vs {q['multilevel']['max_deg']}) "
              f"coll {row['collective_bytes_per_iter'] / 1e6:.2f} MB/iter "
              f"flops/agent {row['per_device_flops']:.2e} "
              f"test acc {row['test_acc']:.3f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--partitioner", default="multilevel",
                    choices=["bfs_kl", "multilevel"],
                    help="partition method the trainer uses (quality of "
                         "both methods is reported per M either way)")
    print(json.dumps(run(partitioner=ap.parse_args().partitioner), indent=2))
