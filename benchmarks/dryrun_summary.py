"""§Dry-run summary table: compile time / peak memory / fit verdict for
every (arch × shape × mesh) from results/dryrun/*.json."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"
HBM_PER_CHIP = 16 * 2 ** 30     # v5e


def run() -> list[dict]:
    rows = []
    for path in sorted(RESULTS_DIR.glob("*.json")):
        if "__opt" in path.name:
            continue
        r = json.loads(path.read_text())
        peak = r["memory"]["peak_bytes"] or 0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compile_s": r["compile_s"],
            "peak_gib": round(peak / 2 ** 30, 2),
            "fits": peak < HBM_PER_CHIP,
            "notes": "; ".join(r.get("notes", [])),
        })
    if not rows:
        print(f"[dryrun-summary] no results in {RESULTS_DIR}")
        return rows
    print(f"{'arch':24s} {'shape':11s} {'mesh':8s} {'compile':>8s} "
          f"{'peak GiB':>9s} fit")
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:11s} {r['mesh']:8s} "
              f"{r['compile_s']:8.1f} {r['peak_gib']:9.2f} "
              f"{'ok' if r['fits'] else 'OOM!'}")
    n_fit = sum(r["fits"] for r in rows)
    print(f"[dryrun-summary] {n_fit}/{len(rows)} combinations fit "
          f"{HBM_PER_CHIP / 2**30:.0f} GiB/chip")
    return rows


if __name__ == "__main__":
    run()
