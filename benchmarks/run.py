"""Benchmark orchestrator — one entry per paper table/figure + the
beyond-paper additions.  Prints ``name,value,derived`` CSV lines and writes
results/bench/*.json.

  table3_speedup    paper Table 3 (serial vs parallel ADMM wall time)
  fig2_accuracy     paper Figure 2 (ADMM vs SGD-family optimizers)
  roofline          §Roofline terms per (arch × shape), from the dry-run
  layerwise         beyond-paper: blockwise ADMM on a transformer
  kernels           per-kernel micro-latency (oracle path on CPU)

Run everything: ``PYTHONPATH=src python -m benchmarks.run``
Subset:         ``... -m benchmarks.run --only table3_speedup,roofline``
CI smoke:       ``... benchmarks/run.py --quick`` — emits the repo-root
``BENCH_block_sparsity.json`` / ``BENCH_speedup.json`` / ``BENCH_serving.json``
quick payloads and validates them with benchmarks/check_bench.py (schema +
the compressed-vs-dense adjacency, p2p-vs-allgather wire-byte, and serving
hit-rate/latency regression guards).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:       # allow `python benchmarks/run.py`
    sys.path.insert(0, str(REPO_ROOT))

OUT_DIR = REPO_ROOT / "results" / "bench"


def bench_table3_speedup() -> list[tuple[str, float, str]]:
    from benchmarks import speedup
    rows = speedup.run(epochs=15, hidden=256)
    out = []
    for r in rows:
        tag = f"table3/{r['dataset']}/{r['mode']}"
        out.append((f"{tag}/serial_s", r["serial_total_s"], ""))
        out.append((f"{tag}/parallel_s", r["parallel_total_s"], ""))
        out.append((f"{tag}/speedup", r["speedup"],
                    "paper: 3.30x (Computers); 2.98x (Photo)"))
        out.append((f"{tag}/adjacency_mb",
                    round(r["adjacency_bytes"] / 1e6, 3), ""))
    (OUT_DIR / "table3_speedup.json").write_text(json.dumps(rows, indent=2))
    return out


def bench_fig2_accuracy() -> list[tuple[str, float, str]]:
    from benchmarks import accuracy
    res = accuracy.run(dataset="amazon_photo_mini", epochs=40, hidden=256)
    out = []
    for name, curve in res["curves"].items():
        out.append((f"fig2/{res['dataset']}/{name}/final_test_acc",
                    round(curve["test"][-1], 4), ""))
    (OUT_DIR / "fig2_accuracy.json").write_text(json.dumps(res, indent=2))
    return out


def bench_roofline() -> list[tuple[str, float, str]]:
    from benchmarks import roofline
    rows = roofline.run()
    out = []
    for r in rows:
        key = f"roofline/{r['arch']}/{r['shape']}"
        out.append((f"{key}/dominant_term_s",
                    max(r["compute_s"], r["memory_lo_s"],
                        r["collective_s"]), r["dominant"]))
    (OUT_DIR / "roofline.json").write_text(json.dumps(rows, indent=2))
    return out


def bench_layerwise() -> list[tuple[str, float, str]]:
    from benchmarks import layerwise_bench
    res = layerwise_bench.run(arch="qwen2-7b", iters=6)
    (OUT_DIR / "layerwise.json").write_text(json.dumps(res, indent=2))
    return [("layerwise/qwen2-7b/admm_ce", res["admm_ce"],
             f"adam_ce={res['adam_ce']:.4f} same wall-time"),
            ("layerwise/qwen2-7b/residual", res["admm_residual"], "")]


def bench_kernels() -> list[tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    out = []

    def timeit(fn, *args, n=5):
        r = fn(*args)
        jax.block_until_ready(r[0] if isinstance(r, tuple) else r)
        t0 = time.perf_counter()
        for _ in range(n):
            r = fn(*args)
            jax.block_until_ready(r[0] if isinstance(r, tuple) else r)
        return (time.perf_counter() - t0) / n * 1e6

    a = jnp.asarray(rng.normal(size=(3, 256, 256)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(3, 256, 128)).astype(np.float32))
    mask = jnp.asarray([True, True, False])
    us = timeit(jax.jit(lambda a, z: ref.community_spmm_ref(a, z, mask)),
                a, z)
    out.append(("kernels/community_spmm_ref_us", round(us, 1),
                "jnp oracle on CPU; pallas path targets TPU"))

    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)).astype(np.float32))
    us = timeit(jax.jit(lambda q: ref.flash_attention_ref(q, q, q)), q)
    out.append(("kernels/flash_attention_ref_us", round(us, 1), ""))

    x = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.normal(size=(2, 256, 4)).astype(np.float32)))
    av = -jnp.abs(jnp.asarray(rng.normal(size=(4,)).astype(np.float32)))
    bm = jnp.asarray(rng.normal(size=(2, 256, 1, 32)).astype(np.float32))
    us = timeit(jax.jit(lambda x, dt: ref.ssd_scan_ref(x, dt, av, bm, bm,
                                                       chunk=64)), x, dt)
    out.append(("kernels/ssd_scan_ref_us", round(us, 1), ""))
    return out


def bench_dryrun_summary() -> list[tuple[str, float, str]]:
    from benchmarks import dryrun_summary
    rows = dryrun_summary.run()
    (OUT_DIR / "dryrun_summary.json").write_text(json.dumps(rows, indent=2))
    n_fit = sum(r["fits"] for r in rows)
    return [("dryrun/combinations_fitting_hbm", n_fit,
             f"of {len(rows)} lowered+compiled")]


def bench_perf_report() -> list[tuple[str, float, str]]:
    from benchmarks import perf_report
    rows = perf_report.run()
    (OUT_DIR / "perf_report.json").write_text(json.dumps(rows, indent=2))
    return [(f"perf/{r['pair']}/collective_speedup",
             r["speedup_collective"], "") for r in rows]


def bench_ablation() -> list[tuple[str, float, str]]:
    from benchmarks import ablation_communities
    rows = ablation_communities.run(epochs=15, parts=(1, 3, 6))
    (OUT_DIR / "ablation_communities.json").write_text(
        json.dumps(rows, indent=2))
    return [(f"ablation/M={r['M']}/test_acc", r["test_acc"],
             f"cut={r['edge_cut_frac']}") for r in rows]


BENCHES = {
    "table3_speedup": bench_table3_speedup,
    "fig2_accuracy": bench_fig2_accuracy,
    "roofline": bench_roofline,
    "dryrun_summary": bench_dryrun_summary,
    "perf_report": bench_perf_report,
    "layerwise": bench_layerwise,
    "ablation": bench_ablation,
    "kernels": bench_kernels,
}


def quick() -> None:
    """CI smoke: quick BENCH_*.json emission + schema/regression checks."""
    from benchmarks import block_sparsity, check_bench, serving, speedup
    block_sparsity.main(quick=True)
    speedup.main(quick=True)
    serving.main(quick=True)
    check_bench.main()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: quick BENCH_*.json + check_bench")
    args = ap.parse_args()
    if args.quick:
        if args.only:
            ap.error("--quick runs a fixed smoke set; drop --only or run "
                     "the subset without --quick")
        quick()
        return
    names = args.only.split(",") if args.only else list(BENCHES)
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    print("name,value,derived")
    for name in names:
        rows = BENCHES[name]()
        for key, value, derived in rows:
            print(f"{key},{value},{derived}")


if __name__ == "__main__":
    main()
