"""Serving latency under Zipf traffic: the community cache pays.

Trains a small community model on the size-skewed M=32 power-law graph
(the benchmark graph every layout/transport number is measured on),
builds a ``serve.CommunityServer`` over it, and fires a Zipf(1.1) node
request stream — the heavy-tailed "millions of users" traffic shape —
through the batched serving path twice:

  * **cached** — embedding + halo caches at the pinned capacities with
    Zipf-aware admission; steady-state batches are answered by per-block
    row gathers;
  * **cold** — ``ServeConfig(cache_enabled=False)``: the same compiled
    programs with capacity-0 caches, so every batch recomputes its
    communities' L-hop chains through the packed kernels.  Bitwise
    parity between the two paths is asserted on a probe set.

Reports p50/p99 per-batch latency, QPS and steady-state hit rate as the
repo-root ``BENCH_serving.json`` (CI artifact, guarded by
benchmarks/check_bench.py: hit-rate floor, cached p99 below the cold
p50, ≥5× p50 speedup, zero-collective hit path).

  PYTHONPATH=src python benchmarks/serving.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

ZIPF_S = 1.1
M = 32
BATCH = 64
EMBED_CAPACITY = 40
HALO_CAPACITY = 64


def _percentiles(times_s: list) -> dict:
    arr = np.asarray(times_s, dtype=np.float64) * 1e3
    return {"p50_ms": round(float(np.percentile(arr, 50)), 4),
            "p99_ms": round(float(np.percentile(arr, 99)), 4)}


def _run_stream(server, stream: np.ndarray, batch: int, warmup_frac: float
                ) -> dict:
    """Serve the stream in batches; steady-state timing past the warmup."""
    n_batches = len(stream) // batch
    warmup = max(int(n_batches * warmup_frac), 1)
    times, served = [], 0
    hits0 = total0 = 0
    for i in range(n_batches):
        ids = stream[i * batch:(i + 1) * batch]
        if i == warmup:
            hits0, total0 = server.request_hits, server.request_total
        t0 = time.perf_counter()
        server.serve(ids)
        dt = time.perf_counter() - t0
        if i >= warmup:
            times.append(dt)
            served += len(ids)
    steady_total = server.request_total - total0
    steady_hits = server.request_hits - hits0
    out = _percentiles(times)
    out["qps"] = round(served / max(sum(times), 1e-9), 1)
    out["hit_rate"] = round(steady_hits / max(steady_total, 1), 4)
    out["batches"] = len(times)
    out["warmup_batches"] = warmup
    return out


def _hit_path_report(server) -> dict:
    """Prove the steady-state hit program is collective-free and touches
    nothing full-graph-sized (the same expectations the ``serve_hit``
    analyze config pins in CI)."""
    from repro import analysis
    from repro.analysis import hlo as hlo_mod

    text = server.hit_path_lowered(bucket=BATCH).compile().as_text()
    bound = int(server.dl.plane_rows)
    rep = analysis.analyze_hlo(text, expectations={
        "expect_zero_collectives": True,
        "full_graph_rows": bound,
    }, config="serve_hit")
    census = hlo_mod.hlo_census(text)
    n_coll = sum(v["count"] for v in census.collectives.values())
    return {"analysis_errors": len(rep.errors()),
            "collectives": int(n_coll),
            "full_graph_rows_bound": bound,
            # single-device, zero collectives compiled: nothing crosses
            # a wire on the hit path — the quantity check_bench pins
            "wire_bytes": 0 if n_coll == 0 else -1}


def run(quick: bool = False) -> dict:
    import jax

    from repro.core import gcn, graph
    from repro.core.parallel import ParallelADMMTrainer, TrainerConfig
    from repro.core.subproblems import ADMMConfig
    from repro.serve import CommunityServer, ServeConfig, zipf_node_stream

    epochs = 2 if quick else 5
    requests = 1920 if quick else 6400
    cold_requests = 640 if quick else 1280

    g, part = graph.synthetic_powerlaw_communities(
        M, nodes_per_part=32, attach=2, seed=0, feat_dim=16, size_skew=1.0)
    cfg = gcn.GCNConfig(layer_dims=(16, 32, g.num_classes))
    tr = ParallelADMMTrainer(
        cfg, ADMMConfig(nu=1e-3, rho=1e-3), g, num_parts=M, seed=0,
        part=part, config=TrainerConfig(transport="p2p", compressed=True,
                                        pad_mode="bucketed", packed=True))
    tr.train(epochs)
    train_acc, test_acc, _ = (float(x) for x in tr._metrics(tr.state))

    stream = zipf_node_stream(g.num_nodes, requests, s=ZIPF_S, seed=1)

    served_cfg = ServeConfig(embed_capacity=EMBED_CAPACITY,
                             halo_capacity=HALO_CAPACITY, admission="zipf",
                             max_batch=BATCH)
    server = CommunityServer.from_trainer(tr, served_cfg)
    hit = _run_stream(server, stream, BATCH, warmup_frac=0.25)
    hit_path = _hit_path_report(server)
    hit["wire_bytes"] = hit_path["wire_bytes"]

    cold_cfg = ServeConfig(embed_capacity=EMBED_CAPACITY,
                           halo_capacity=HALO_CAPACITY, admission="zipf",
                           max_batch=BATCH, cache_enabled=False)
    cold_server = CommunityServer.from_trainer(tr, cold_cfg)
    cold = _run_stream(cold_server, stream[:cold_requests], BATCH,
                       warmup_frac=0.25)
    cold.pop("hit_rate", None)

    # parity: the same probe nodes through both engines, bitwise
    probe = np.unique(stream[:512])
    a = server.serve(probe)
    b = cold_server.serve(probe)
    parity = {"bitwise_equal": bool(np.array_equal(a, b)),
              "max_delta": float(np.abs(a - b).max()),
              "nodes": int(len(probe))}

    jax.block_until_ready(server.z0_plane)
    return {
        "quick": bool(quick),
        "M": M,
        "num_nodes": int(g.num_nodes),
        "zipf_s": ZIPF_S,
        "requests": int(requests),
        "batch": BATCH,
        "embed_capacity": EMBED_CAPACITY,
        "halo_capacity": HALO_CAPACITY,
        "admission": "zipf",
        "train": {"epochs": epochs, "train_acc": round(train_acc, 4),
                  "test_acc": round(test_acc, 4)},
        "hit": hit,
        "cold": cold,
        "speedup_p50": round(cold["p50_ms"] / max(hit["p50_ms"], 1e-9), 2),
        "parity": parity,
        "hit_path": hit_path,
        "stats": server.stats(),
    }


def main(quick: bool = False, out: "str | None" = None) -> dict:
    payload = run(quick=quick)
    path = pathlib.Path(out) if out else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"
    path.write_text(json.dumps(payload, indent=2))
    h, c = payload["hit"], payload["cold"]
    print(f"[serving] hit_rate={h['hit_rate']} p50={h['p50_ms']}ms "
          f"p99={h['p99_ms']}ms qps={h['qps']} | cold p50={c['p50_ms']}ms "
          f"| speedup_p50={payload['speedup_p50']}x "
          f"| parity={payload['parity']['bitwise_equal']}")
    print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests/epochs (CI smoke)")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
