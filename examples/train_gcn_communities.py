"""End-to-end driver: community-parallel ADMM GCN training (the paper's
Parallel ADMM) for a few hundred epochs, with partition diagnostics,
checkpointing and the bf16-message option.

Run with multiple agents (each community on its own host device):
  XLA_FLAGS=--xla_force_host_platform_device_count=3 \\
  PYTHONPATH=src python examples/train_gcn_communities.py --parts 3 \\
      --epochs 200 --comm-bf16
"""
import argparse

import numpy as np

from repro import checkpoint as ckpt
from repro.core import gcn, graph
from repro.core.parallel import ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="amazon_photo_mini",
                    choices=list(graph.DATASET_STATS))
    ap.add_argument("--parts", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--comm-bf16", action="store_true",
                    help="bf16 message payloads (§Perf optimization)")
    ap.add_argument("--compressed", action="store_true",
                    help="block-compressed (ELL) adjacency: each shard "
                         "holds only its communities' neighbour blocks — "
                         "no dense (M,M,n_pad,n_pad) tensor on device")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route aggregation through the Pallas kernels "
                         "(TPU; set REPRO_PALLAS_INTERPRET=1 elsewhere)")
    ap.add_argument("--transport", default=None,
                    choices=["p2p", "allgather"],
                    help="Z/U/q exchange: neighbour-only ppermute rounds "
                         "(p2p, default with --compressed) or the masked "
                         "all-gather oracle (default otherwise)")
    ap.add_argument("--partitioner", default="multilevel",
                    choices=["bfs_kl", "multilevel"],
                    help="community detection: multilevel coarsen→partition"
                         "→uncoarsen (METIS scheme, sharding.multilevel — "
                         "lower edge cut, hence less p2p wire) or the "
                         "BFS-grow + Kernighan-Lin stand-in (bfs_kl)")
    ap.add_argument("--pad-mode", default="bucketed",
                    choices=["global", "bucketed"],
                    help="community padding: one global n_pad (every "
                         "community padded to the largest) or size-aware "
                         "power-of-two-ish buckets — pad FLOPs are guarded "
                         "out of the ELL kernel and the p2p exchange wires "
                         "row-exact payloads (true rows only)")
    ap.add_argument("--adjacency-bf16", action="store_true",
                    help="store the ELL adjacency blocks in bf16 (half the "
                         "resident bytes; aggregation still accumulates "
                         "f32) — requires --compressed")
    ap.add_argument("--packed", action="store_true",
                    help="store Z/U/z0 as packed Σ-bucket-rows planes "
                         "(docs/layout.md) — requires --compressed and the "
                         "p2p transport; bitwise-equal iterates, fewer "
                         "resident rows on skewed graphs")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffer the p2p rounds against the ELL "
                         "aggregation (requires --packed)")
    ap.add_argument("--fused", action="store_true",
                    help="fuse the packed ELL aggregation with the "
                         "Z-update GEMM in one Pallas pass (docs/layout.md "
                         "§5) — requires --packed; the aggregated "
                         "intermediate never touches HBM")
    ap.add_argument("--batch-fraction", type=float, default=None,
                    help="stochastic community minibatching: sample this "
                         "fraction of shards per ADMM round (seeded, "
                         "balance-aware batches; docs/minibatch.md) — "
                         "requires --packed; 1.0 is bitwise full-batch")
    ap.add_argument("--stale-decay", type=float, default=0.5,
                    help="per-round decay of unsampled communities' "
                         "consensus penalty weight (d_r = decay^age)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="seed of the community batch sampler")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    g = graph.synthetic_sbm(args.dataset, seed=0)
    hyper = 1e-3 if "computers" in args.dataset else 1e-4
    cfg = gcn.GCNConfig(layer_dims=(g.features.shape[1], args.hidden,
                                    g.num_classes))
    admm = ADMMConfig(nu=hyper, rho=hyper)

    part = graph.partition_graph(g.num_nodes, g.edges, args.parts, seed=0,
                                 method=args.partitioner)
    q = graph.partition_quality(g.num_nodes, g.edges, part, args.parts)
    print(f"partition [{args.partitioner}]: {args.parts} communities, sizes "
          f"{np.bincount(part).tolist()}, edge cut "
          f"{q['edge_cut']}/{g.num_edges} ({100 * q['cut_frac']:.1f}%), "
          f"balance {q['balance']:.3f}, block max_deg {q['max_deg']}")

    # every mode flag above maps 1:1 onto a TrainerConfig field by its
    # argparse dest — the config does all cross-flag validation
    trainer = ParallelADMMTrainer(cfg, admm, g, num_parts=args.parts,
                                  seed=0, part=part,
                                  config=TrainerConfig.from_cli_args(args))
    print(f"mesh: {dict(trainer.mesh.shape)}; neighbour topology:\n"
          f"{np.asarray(trainer.data.neighbor_mask).astype(int)}")
    cs = trainer.comm_stats
    print(f"collective/iter [{cs['transport']}]: full "
          f"{cs['full_bytes'] / 1e6:.2f} MB, neighbour-only "
          f"{cs['needed_bytes'] / 1e6:.2f} MB "
          f"({cs['nnz_blocks']}/{cs['dense_blocks']} blocks, "
          f"{100 * cs['savings_ratio']:.0f}% saved), scheduled wire "
          f"{cs['wire_bytes'] / 1e6:.2f} MB")
    sizes = trainer.layout.sizes
    print(f"padding [{cs['pad_mode']}]: community sizes "
          f"{int(sizes.min())}..{int(sizes.max())} padded to "
          f"{'per-size buckets' if args.pad_mode == 'bucketed' else 'one'} "
          f"n_pad={trainer.layout.n_pad}; residual pad rows "
          f"{cs['pad_rows']} -> {cs['pad_bytes'] / 1e3:.1f} kB payload "
          f"padding and {cs['pad_flops'] / 1e6:.1f} MFLOP "
          f"({100 * cs['pad_flop_frac']:.1f}%) pad work per iteration")
    adj = cs["adjacency"]
    mode = "compressed (ELL"
    mode += ", bf16 blocks)" if args.adjacency_bf16 else ")"
    mode = mode if args.compressed else "dense"
    print(f"adjacency on device [{mode}]: {adj['resident_bytes'] / 1e6:.2f} "
          f"MB (dense would be {adj['dense_bytes'] / 1e6:.2f} MB, "
          f"max_deg {adj['max_deg']})")
    st = cs["state"]
    print(f"resident state [{'packed' if st['packed'] else 'strided'}]: "
          f"{st['rows']} rows / {st['resident_bytes'] / 1e6:.2f} MB "
          f"(strided {st['strided_rows']} rows / "
          f"{st['strided_equiv_bytes'] / 1e6:.2f} MB, Σ-bucket floor "
          f"{st['bucket_rows']} rows)")
    if "overlap" in cs and cs["overlap"]["enabled"]:
        ov = cs["overlap"]
        print(f"overlap: {100 * ov['overlap_efficiency']:.2f}% of "
              f"{cs['wire_bytes'] / 1e6:.2f} MB wire hidden across "
              f"{ov['num_groups']} arrival groups "
              f"({ov['num_rounds']} rounds)")
    if cs["minibatch"]["enabled"]:
        mb = cs["minibatch"]
        print(f"minibatch [f={mb['batch_fraction']}, decay="
              f"{mb['stale_decay']}]: {mb['num_batches']} batches/cycle "
              f"{mb['schedule']}, wire {mb['full_wire_bytes'] / 1e6:.2f} MB "
              f"-> mean sampled {mb['mean_sampled_wire_bytes'] / 1e6:.2f} "
              f"MB, sweep rows {mb['full_state_rows']} -> mean "
              f"{mb['mean_sampled_state_rows']:.0f}")

    log = trainer.train(args.epochs, verbose=False)
    stride = max(1, args.epochs // 10)
    for i in range(0, len(log.epoch), stride):
        print(f"epoch {log.epoch[i]:4d} train {log.train_acc[i]:.3f} "
              f"test {log.test_acc[i]:.3f} residual {log.residual[i]:.2e}")
    print(f"final: train {log.train_acc[-1]:.3f} test {log.test_acc[-1]:.3f}")

    if args.ckpt_dir:
        path = ckpt.save(args.ckpt_dir,
                         {"weights": list(trainer.state.weights)},
                         step=args.epochs)
        print(f"checkpoint -> {path}")


if __name__ == "__main__":
    main()
