"""Serving example: cached community-block GCN inference under Zipf load.

Trains a small community-partitioned GCN, builds a ``CommunityServer``
over the trained weights, and contrasts three serving modes on the same
heavy-tailed request stream:

  * cached + Zipf-aware admission (the production path),
  * cached + plain LRU admission,
  * cache disabled (every batch recomputes its community's 2-hop chain
    through the packed ELL kernels — the baseline the cache beats).

Then a feature update shows incremental invalidation: only the read
closure of the touched community recomputes; the rest keeps serving out
of cache.

Run:  PYTHONPATH=src python examples/serve_gcn.py
"""
import time

import numpy as np

from repro.core import gcn, graph
from repro.core.parallel import ParallelADMMTrainer, TrainerConfig
from repro.core.subproblems import ADMMConfig
from repro.serve import CommunityServer, ServeConfig, zipf_node_stream

M = 12
BATCH = 64
REQUESTS = 1536


def drive(server, stream):
    n_batches = len(stream) // BATCH
    warmup = max(n_batches // 4, 1)
    times = []
    h0 = t0 = 0
    for i in range(n_batches):
        if i == warmup:
            h0, t0 = server.request_hits, server.request_total
        tic = time.perf_counter()
        server.serve(stream[i * BATCH:(i + 1) * BATCH])
        if i >= warmup:
            times.append(time.perf_counter() - tic)
    ms = np.asarray(times) * 1e3
    hit = (server.request_hits - h0) / max(server.request_total - t0, 1)
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99)), hit


def main():
    g, part = graph.synthetic_powerlaw_communities(
        M, nodes_per_part=24, attach=2, seed=0, feat_dim=16, size_skew=1.0)
    cfg = gcn.GCNConfig(layer_dims=(16, 32, g.num_classes))
    tr = ParallelADMMTrainer(
        cfg, ADMMConfig(nu=1e-3, rho=1e-3), g, num_parts=M, seed=0,
        part=part, config=TrainerConfig(transport="p2p", compressed=True,
                                        pad_mode="bucketed", packed=True))
    print(f"training M={M} community GCN on N={g.num_nodes}...")
    tr.train(3)
    _, test_acc, _ = tr._metrics(tr.state)
    print(f"test_acc={float(test_acc):.4f}\n")

    stream = zipf_node_stream(g.num_nodes, REQUESTS, s=1.1, seed=1)
    modes = [
        ("zipf-admission cache", ServeConfig(embed_capacity=M + M // 4,
                                             admission="zipf")),
        ("plain-LRU cache     ", ServeConfig(embed_capacity=M + M // 4,
                                             admission="lru")),
        ("cache disabled      ", ServeConfig(cache_enabled=False)),
    ]
    print(f"Zipf(1.1) x {REQUESTS} requests, batch {BATCH}:")
    servers = {}
    for name, scfg in modes:
        srv = CommunityServer.from_trainer(tr, scfg)
        p50, p99, hit = drive(srv, stream)
        servers[name] = srv
        print(f"  {name}  p50 {p50:7.3f} ms  p99 {p99:7.3f} ms  "
              f"hit rate {hit:.3f}")

    # incremental invalidation: touch one node, only its read closure pays
    srv = servers[modes[0][0]]
    node = int(stream[0])
    feats = np.asarray(g.features)[[node]] + 0.1
    rep = srv.update_features([node], feats)
    dirty = [len(c) for c in rep["dirty"]]
    print(f"\nfeature update to node {node} (community "
          f"{int(srv.node_comm[node])}): dirty communities per hop "
          f"{dirty} of {M}, dropped {len(rep['embed'])} embed / "
          f"{len(rep['halo'])} halo entries")
    p50, p99, hit = drive(srv, stream)
    print(f"  post-update           p50 {p50:7.3f} ms  p99 {p99:7.3f} ms  "
          f"hit rate {hit:.3f}  (cache refilled)")


if __name__ == "__main__":
    main()
