"""Beyond the paper: layerwise (blockwise) ADMM training of an assigned
transformer architecture — the GCN paper's layer splitting mapped onto a
transformer stack (DESIGN.md §3).  Compares against Adam on the same fixed
batch.

Run:  PYTHONPATH=src python examples/train_transformer_admm.py \\
          --arch qwen2-7b --iters 10
(reduced configs on CPU; on a TPU mesh the stacked layer axis shards over
'model' — see tests/test_layerwise.py::test_layerwise_admm_sharded_runs)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.layerwise import LayerwiseADMMTrainer
from repro.core.subproblems import ADMMConfig
from repro.models.build import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--nu", type=float, default=1e-2)
    ap.add_argument("--rho", type=float, default=1e-2)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)),
        "targets": jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)),
    }
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.frontend.num_embeddings,
            cfg.d_model)).astype(np.float32))

    trainer = LayerwiseADMMTrainer(cfg, ADMMConfig(nu=args.nu, rho=args.rho))
    state, z0 = trainer.init(jax.random.key(0), batch)
    it = jax.jit(lambda s: trainer.iteration(s, z0, batch["targets"]))

    ce, res = trainer.metrics(state, z0, batch["targets"])
    print(f"[admm] init     ce {float(ce):.4f} residual {float(res):.2e}")
    for i in range(args.iters):
        state = it(state)
        if (i + 1) % 2 == 0 or i == args.iters - 1:
            ce, res = trainer.metrics(state, z0, batch["targets"])
            print(f"[admm] iter {i + 1:3d} ce {float(ce):.4f} "
                  f"residual {float(res):.2e}")

    # Adam reference on the same batch
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    opt_state = model.init_optimizer().init(params)
    step = jax.jit(model.train_step)
    for _ in range(args.iters):
        params, opt_state, m = step(params, opt_state, batch)
    print(f"[adam] {args.iters} steps -> ce {float(m['ce']):.4f}")


if __name__ == "__main__":
    main()
