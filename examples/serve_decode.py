"""Serving example: batched KV-cache decode across architecture families —
full-cache attention (qwen2), compressed-latent MLA (deepseek-v3), constant
-state SSM (mamba2) and sliding-window rolling cache (long-context mode).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.build import make_model


def decode_demo(arch: str, rolling: bool = False, steps: int = 12,
                batch: int = 4, max_len: int = 64):
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if rolling and cfg.arch_type not in ("ssm", "hybrid"):
        cfg = dataclasses.replace(cfg, sliding_window=16)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    caches = model.init_cache(batch, max_len, rolling=rolling)
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t,
                                                     rolling=rolling))
    tok = jnp.zeros((batch, 1), jnp.int32)
    logits, caches = step(params, caches, tok)        # compile
    t0 = time.perf_counter()
    toks = []
    for _ in range(steps):
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
        logits, caches = step(params, caches, tok)
    dt = (time.perf_counter() - t0) / steps * 1e3
    cache_mb = sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(caches)) / 2**20
    mode = "rolling-window" if rolling else "full-cache"
    print(f"{arch:22s} [{mode:14s}] {dt:7.2f} ms/token  "
          f"cache {cache_mb:7.1f} MiB  tokens {toks[:6]}...")


def main():
    print("batched greedy decode, reduced configs, CPU:")
    decode_demo("qwen2-7b")               # GQA full cache
    decode_demo("deepseek-v3-671b")       # MLA compressed-latent cache
    decode_demo("mamba2-1.3b")            # SSM constant state
    decode_demo("recurrentgemma-9b")      # hybrid RG-LRU + local attn
    decode_demo("qwen2-7b", rolling=True)  # sliding-window long-context mode


if __name__ == "__main__":
    main()
