"""Quickstart: the paper in ~40 lines.

Builds a 2-layer GCN on a synthetic Amazon-Photo-statistics graph, trains
it with the community-based ADMM algorithm (serial: one agent), and
compares against Adam — the paper's §4.2 in miniature.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import gcn, graph
from repro.core.serial import BaselineTrainer, SerialADMMTrainer
from repro.core.subproblems import ADMMConfig


def main():
    # synthetic stand-in with Amazon Photo statistics (Table 2)
    g = graph.synthetic_sbm("amazon_photo_mini", seed=0)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"{g.num_classes} classes")

    # the paper's model: 2-layer GCN (hidden width reduced for CPU speed;
    # the paper uses 1000 — pass hidden=1000 to reproduce exactly)
    hidden = 128
    cfg = gcn.GCNConfig(layer_dims=(g.features.shape[1], hidden,
                                    g.num_classes))
    admm = ADMMConfig(nu=1e-4, rho=1e-4)   # paper's Photo hyperparams

    print("\n--- Serial ADMM (Algorithm 1, one community) ---")
    trainer = SerialADMMTrainer(cfg, admm, g, seed=0)
    log = trainer.train(25, log_every=5, verbose=True)

    print("\n--- Adam baseline (paper §4.2, lr 1e-3) ---")
    adam = BaselineTrainer(cfg, g, "adam", 1e-3, seed=0)
    alog = adam.train(25, verbose=False)
    print(f"adam final: train {alog.train_acc[-1]:.3f} "
          f"test {alog.test_acc[-1]:.3f}")

    print(f"\nADMM  final: train {log.train_acc[-1]:.3f} "
          f"test {log.test_acc[-1]:.3f}")
    print("(paper finding: ADMM reaches comparable accuracy and converges "
          "fastest; see benchmarks/accuracy.py for the full Figure 2 run)")


if __name__ == "__main__":
    main()
